"""Serving example: batched prompt-then-generate for three architecture
families — SSM (mamba2, O(1) state), hybrid (recurrentgemma, RG-LRU + local
attention ring cache), and the enc-dec whisper backbone consuming stubbed
audio-frame embeddings.

  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models import transformer as T


def serve_arch(arch, batch=2, prompt=12, gen=8):
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    total = prompt + gen
    cache = T.init_cache(cfg, batch, total)
    if cfg.arch_type == "encdec":
        # stubbed conv-frontend output: precomputed mel-frame embeddings
        enc = 0.1 * jax.random.normal(
            jax.random.PRNGKey(1), (batch, cfg.encoder_seq, cfg.d_model))
        enc_out, _, _ = T.forward(
            params, {"tokens": jnp.zeros((batch, 1), jnp.int32),
                     "enc_emb": enc}, cfg)
        # populate cross caches from the encoder (per decoder layer)
        # simple: recompute cross K/V per layer via forward(return_cache)
        _, _, full = T.forward(params,
                               {"tokens": jnp.zeros((batch, 1), jnp.int32),
                                "enc_emb": enc}, cfg, return_cache=True)
        cache["blocks"]["ck"] = full["blocks"]["ck"]
        cache["blocks"]["cv"] = full["blocks"]["cv"]

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(cfg.vocab_size, size=(batch, prompt)),
                          jnp.int32)
    prefill = jax.jit(lambda p, c, toks: T.prefill(p, c, toks, cfg))
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))
    t0 = time.time()
    logits, cache = prefill(params, cache, prompts)   # one jitted call
    assert bool(jnp.isfinite(logits).all())
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    outs = [int(tok[0, 0])]
    t0 = time.time()
    for t in range(prompt, total - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(t))
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        outs.append(int(tok[0, 0]))
    dt = time.time() - t0
    print(f"  {arch:22s} generated {outs} "
          f"(prefill {t_prefill:.2f}s, "
          f"{dt/max(gen-1,1)*1e3:.0f} ms/token-step incl. compile)")


def main():
    print("batched serving across architecture families:")
    for arch in ("mamba2-2.7b", "recurrentgemma-2b", "whisper-base"):
        serve_arch(arch)
    print("OK")


if __name__ == "__main__":
    main()
