"""Quickstart: train a reduced qwen2-family model with Omnivore compute
groups, then greedy-decode from it. Runs on CPU in ~1 minute.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.async_sgd import make_grouped_train_step
from repro.core.compute_groups import GroupSpec, group_batch_split
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.optim.sgd import init_momentum


def main():
    cfg = get_smoke_config("qwen2-7b")
    g = 4                                     # compute groups (paper §IV)
    spec = GroupSpec(num_groups=g, num_devices=max(g, jax.device_count()))
    print(f"{cfg.name}: g={g}, staleness={spec.staleness}, "
          f"implicit momentum={spec.implicit_momentum:.2f} "
          f"-> tuned explicit momentum {0.9 - spec.implicit_momentum:.2f}")

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    mom = init_momentum(params)
    step = jax.jit(make_grouped_train_step(
        lambda p, b: T.lm_loss(p, b, cfg),
        num_groups=g, lr=0.05,
        momentum=max(0.0, 0.9 - spec.implicit_momentum)))

    data = SyntheticLM(DataConfig(batch_size=16, seq_len=64,
                                  vocab_size=cfg.vocab_size, seed=0))
    losses = []
    for i, batch in enumerate(data.batches(40)):
        params, mom, loss = step(params, mom, group_batch_split(batch, g))
        losses.append(float(loss))
        if i % 10 == 0:
            print(f"  step {i:3d}  loss {loss:.4f}")
    assert losses[-1] < losses[0], "training must reduce loss"

    # greedy decode with KV cache
    cache = T.init_cache(cfg, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))
    out = []
    for t in range(16):
        logits, cache = decode(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("decoded:", out)
    print("OK")


if __name__ == "__main__":
    main()
