"""Quickstart: train a reduced qwen2-family model with Omnivore compute
groups, then greedy-decode from it. Runs on CPU in ~1 minute.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.core.compute_groups import GroupSpec
from repro.engine import Engine
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import transformer as T
from repro.optim.sgd import init_momentum


def main():
    cfg = get_smoke_config("qwen2-7b")
    g = 4                                     # compute groups (paper §IV)
    spec = GroupSpec(num_groups=g, num_devices=g)
    mu = max(0.0, 0.9 - spec.implicit_momentum)
    engine = Engine(lambda p, b: T.lm_loss(p, b, cfg), num_groups=g,
                    lr=0.05, momentum=mu)
    print(f"{cfg.name}: {engine.describe(g, 16 // g)} "
          f"-> tuned explicit momentum {mu:.2f}")

    params = T.init_params(jax.random.PRNGKey(0), cfg)
    mom = init_momentum(params)
    data = SyntheticLM(DataConfig(batch_size=16, seq_len=64,
                                  vocab_size=cfg.vocab_size, seed=0))
    params, mom, losses = engine.run(params, mom, data.batches(40), steps=40,
                                     log_every=10,
                                     log=lambda s: print(" ", s))
    assert losses[-1] < losses[0], "training must reduce loss"

    # greedy decode with KV cache
    cache = T.init_cache(cfg, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))
    out = []
    for t in range(16):
        logits, cache = decode(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("decoded:", out)
    print("OK")


if __name__ == "__main__":
    main()
