"""End-to-end Algorithm 1 demo (the paper's automatic optimizer) on the CNN
workload family the paper studies: cold start -> epoch-wise grid search with
the mu*=0 => halve-g rule -> trained model. Compares against fixed sync and
fixed fully-async strategies.

  PYTHONPATH=src python examples/autotune.py
"""
import numpy as np

from repro.core import hardware_model as hm
from repro.core.auto_optimizer import algorithm1
from repro.core.stat_model import iterations_to_loss
from repro.core.workload import cnn_classify, init_state, make_runner

N_DEVICES = 16
TARGET = 0.5


def fixed_strategy(runner, state, g, mu, eta, steps=400):
    _, losses = runner(state, g=g, mu=mu, eta=eta, steps=steps, probe=True)
    it = iterations_to_loss(np.asarray(losses), TARGET)
    ph = hm.PhaseTimes(t_conv_compute_1=1.0, t_fc=0.06, conv_grad_bytes=0.0)
    he = hm.he_time_per_iteration(g, N_DEVICES, ph)
    return it, he, (he * it if it else None)


def main():
    wl = cnn_classify()
    runner = make_runner(wl, seed=0)
    state = init_state(wl, seed=0)

    print("== Algorithm 1 (cold start + adaptive grid + g-halving) ==")
    ph = hm.PhaseTimes(t_conv_compute_1=1.0, t_fc=0.06, conv_grad_bytes=0.0)
    res = algorithm1(runner, state, n_devices=N_DEVICES, epochs=2,
                     epoch_steps=200, probe_steps=80, phase_times=ph)
    for d in res.decisions:
        print(f"  [{d.phase}] g={d.g} mu={d.mu} eta={d.eta} "
              f"loss={d.loss:.4f}")
    print(f"  chose g={res.g}, mu={res.mu}, eta={res.eta}")

    print("== fixed strategies (paper Fig. 7 comparison) ==")
    from repro.core.implicit_momentum import optimal_explicit_momentum
    mu_chosen = optimal_explicit_momentum(res.g, 0.9)
    for name, g, mu in (("sync", 1, 0.9), ("async", N_DEVICES, 0.0),
                        (f"omnivore(g={res.g})", res.g, mu_chosen)):
        it, he, total = fixed_strategy(runner, state, g, mu, 0.05)
        print(f"  {name:18s} iters_to_{TARGET}={it} "
              f"he={he:.4f}s/it total={total and round(total,2)}s")
    # On this small, fast-converging CPU workload the optimizer picks a
    # low-asynchrony strategy — the same conclusion the paper reaches on its
    # CPU-S cluster (§VI-B3), where fully-synchronous won.
    print("OK")


if __name__ == "__main__":
    main()
