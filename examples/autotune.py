"""End-to-end Algorithm 1 demo (the paper's automatic optimizer) on the CNN
workload family the paper studies: cold start -> epoch-wise grid search with
the mu*=0 => halve-g rule -> trained model. Compares against fixed sync and
fixed fully-async strategies. Then the heterogeneous half: black-box-profile
this container's actual jitted step, plan a mixed 8xGPU+8xCPU cluster with
the time-to-convergence planner, validate the plan against the
discrete-event simulator and train at the planned allocation with
share-weighted grouped updates.

  PYTHONPATH=src python examples/autotune.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import cluster
from repro.core import hardware_model as hm
from repro.core.auto_optimizer import algorithm1
from repro.core.implicit_momentum import optimal_explicit_momentum
from repro.core.stat_model import iterations_to_loss
from repro.core.workload import cnn_classify, init_state, make_runner
from repro.engine import Engine

N_DEVICES = 16
TARGET = 0.5


def fixed_strategy(runner, state, g, mu, eta, steps=400):
    _, losses = runner(state, g=g, mu=mu, eta=eta, steps=steps, probe=True)
    it = iterations_to_loss(np.asarray(losses), TARGET)
    ph = hm.PhaseTimes(t_conv_compute_1=1.0, t_fc=0.06, conv_grad_bytes=0.0)
    he = hm.he_time_per_iteration(g, N_DEVICES, ph)
    return it, he, (he * it if it else None)


def hetero_plan_and_train(wl, runner, state):
    """Returns the trained Engine (its metric registry feeds --metrics-out
    / the HE x SE report)."""
    """Profile -> plan -> validate -> train on a mixed 8xGPU+8xCPU cluster."""
    params = state[0]
    batch0 = jax.tree.map(lambda x: x[0],
                          wl.sample_batches(jax.random.PRNGKey(7), 1,
                                            wl.batch_size))
    # black-box probe: time THIS container's jitted step — the planner only
    # ever sees examples/s, never what the device is
    vg = jax.jit(jax.value_and_grad(wl.loss_fn))
    local = cluster.profiled_spec(
        cluster.DeviceSpec("local-cpu", "cpu", peak_flops=1e12, mem_bw=1e11,
                           net_bw=1.25e9),
        vg, (params, batch0), batch_size=wl.batch_size)
    print(f"  profiled local-cpu: {local.throughput:.0f} examples/s")
    # a simulated GPU node: same black-box contract, 6x the measured rate
    gpu = dataclasses.replace(cluster.get_device("gpu-g2.2xlarge"),
                              name="sim-gpu", throughput=6.0 * local.throughput)
    devices = (gpu,) * 8 + (local,) * 8
    t_fc = 0.06 * wl.batch_size / local.throughput   # merged-FC service time
    plan = cluster.best_allocation(devices, global_batch=wl.batch_size,
                                   t_fc=t_fc, mu_star_total=0.9)
    print(plan.describe())
    sim = cluster.simulate_hetero(t_conv=plan.group_times, t_fc=t_fc,
                                  iters=2000, exponential=False)
    err = abs(sim.time_per_iteration - plan.t_iteration) / plan.t_iteration
    print(f"  sim {sim.time_per_iteration * 1e3:.2f}ms/it vs analytic "
          f"{plan.t_iteration * 1e3:.2f}ms/it (err {err:.1%}), "
          f"mean staleness {sim.mean_staleness:.2f}")

    # train at the planned allocation: throughput-proportional microbatches
    # + share-weighted grouped updates (merged-FC head included) — the
    # same engine step train.py and Algorithm 1 drive
    mu = optimal_explicit_momentum(plan.g, 0.9)
    engine = Engine(wl.loss_fn, num_groups=plan.g, lr=0.05, momentum=mu,
                    head_filter=wl.head_filter, group_weights=plan.weights,
                    micro_sizes=plan.allocation.microbatches)
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = wl.sample_batches(jax.random.PRNGKey(11), 60, wl.batch_size)
    batch_iter = (jax.tree.map(lambda x: x[t], batches) for t in range(60))
    _, _, losses = engine.run(params, mom, batch_iter, steps=60)
    print(f"  weighted grouped train @ g={plan.g}, mu={mu:.2f}: "
          f"loss {losses[0]:.4f} -> {np.mean(losses[-5:]):.4f} "
          f"({engine.telemetry.median_step_s() * 1e3:.1f} ms/step)")

    # per-group service times the planner predicted, recorded into the
    # same metric stream the run's step times land in (one registry =
    # predictions and measurements in one sink file)
    reg = engine.telemetry.registry
    svc = reg.series("group_service_s")
    for gid, t in enumerate(plan.group_times):
        svc.append(float(t), step=gid)
    reg.gauge("planned_g").set(plan.g)

    # HE x SE decomposition: recompute T(g, alloc) from the run's own
    # metric stream against a plan calibrated from that stream
    # (obs.report docstring) — the predict->measure loop, closed
    from repro.obs.report import calibrated_plan, hexse_report
    cal = calibrated_plan(engine.telemetry, g=plan.g,
                          global_batch=wl.batch_size)
    rep = hexse_report(engine.telemetry, cal)
    print("  " + rep.render().replace("\n", "\n  "))

    # and Algorithm 1 seeded by the planner instead of the homogeneous
    # FC-saturation short-circuit
    res = algorithm1(runner, state, n_devices=len(devices), epochs=1,
                     epoch_steps=120, probe_steps=40, plan=plan)
    print(f"  algorithm1(plan) started at g={plan.g}, settled at "
          f"g={res.g}, mu={res.mu}, eta={res.eta}")
    return engine


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--metrics-out", default="",
                    help="sink the hetero-train metric stream (step_s, "
                         "group_service_s, ...) to this JSONL file")
    ap.add_argument("--trace-out", default="",
                    help="export a Chrome trace of the demo's spans "
                         "(engine phases, cluster probe, Algorithm-1 "
                         "probes) to this file")
    args = ap.parse_args(argv)
    from repro.obs import spans
    with spans.maybe_traced(bool(args.trace_out)) as tracer:
        engine = _demo()
    if args.metrics_out:
        from repro.obs import run_metadata
        n = engine.telemetry.registry.to_jsonl(
            args.metrics_out, run_metadata(extra={"demo": "autotune"}))
        print(f"metrics -> {args.metrics_out} ({n} records)")
    if args.trace_out:
        from repro.obs import export_chrome_trace
        n = export_chrome_trace(args.trace_out, tracer=tracer,
                                metrics=engine.telemetry.registry)
        print(f"chrome trace -> {args.trace_out} ({n} events)")


def _demo():
    wl = cnn_classify()
    runner = make_runner(wl, seed=0)
    state = init_state(wl, seed=0)

    print("== Algorithm 1 (cold start + adaptive grid + g-halving) ==")
    ph = hm.PhaseTimes(t_conv_compute_1=1.0, t_fc=0.06, conv_grad_bytes=0.0)
    res = algorithm1(runner, state, n_devices=N_DEVICES, epochs=2,
                     epoch_steps=200, probe_steps=80, phase_times=ph)
    for d in res.decisions:
        print(f"  [{d.phase}] g={d.g} mu={d.mu} eta={d.eta} "
              f"loss={d.loss:.4f}")
    print(f"  chose g={res.g}, mu={res.mu}, eta={res.eta}")

    print("== fixed strategies (paper Fig. 7 comparison) ==")
    mu_chosen = optimal_explicit_momentum(res.g, 0.9)
    for name, g, mu in (("sync", 1, 0.9), ("async", N_DEVICES, 0.0),
                        (f"omnivore(g={res.g})", res.g, mu_chosen)):
        it, he, total = fixed_strategy(runner, state, g, mu, 0.05)
        print(f"  {name:18s} iters_to_{TARGET}={it} "
              f"he={he:.4f}s/it total={total and round(total,2)}s")
    # On this small, fast-converging CPU workload the optimizer picks a
    # low-asynchrony strategy — the same conclusion the paper reaches on its
    # CPU-S cluster (§VI-B3), where fully-synchronous won.

    print("== heterogeneous cluster: profile -> plan -> train ==")
    engine = hetero_plan_and_train(wl, runner, state)
    print("OK")
    return engine


if __name__ == "__main__":
    main()
