"""The paper's own workload end-to-end: train a conv/FC CNN (CaffeNet
family, reduced for CPU) with compute groups, merged-FC synchronous head,
and momentum tuned for the asynchrony level — comparing execution
strategies the way Fig. 7 does.

  PYTHONPATH=src python examples/train_cnn_groups.py [--steps 120]
"""
import argparse
import dataclasses

import jax
import numpy as np

from repro.core.compute_groups import GroupSpec
from repro.core.implicit_momentum import optimal_explicit_momentum
from repro.data.pipeline import DataConfig, SyntheticImages
from repro.engine import Engine
from repro.models import cnn
from repro.optim.sgd import init_momentum

CFG = dataclasses.replace(cnn.LENET, image_size=12, num_classes=4,
                          convs=(cnn.ConvSpec(8, 3, pool=2),), fc_dims=(16,),
                          conv_impl="lowering")   # §III path, custom VJP


def run(g, steps, mu_star_sync=0.9, lr=0.05, batch=16):
    mu = optimal_explicit_momentum(g, mu_star_sync)
    params = cnn.init_params(jax.random.PRNGKey(0), CFG)
    mom = init_momentum(params)
    engine = Engine(lambda p, b: cnn.loss_fn(p, b, CFG), num_groups=g,
                    lr=lr, momentum=mu,
                    head_filter=cnn.head_filter)  # merged-FC: sync head
    data = SyntheticImages(DataConfig(batch_size=batch, image_size=12,
                                      num_classes=4, channels=1, seed=0))
    _, _, losses = engine.run(params, mom, data.batches(steps), steps=steps)
    return mu, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    args = ap.parse_args()
    print("g (groups) | staleness | tuned mu | final loss")
    for g in (1, 2, 4, 8):
        mu, losses = run(g, args.steps)
        spec = GroupSpec(num_groups=g, num_devices=16)
        print(f"  g={g:2d}     |    {spec.staleness}      |  {mu:.2f}   | "
              f"{np.mean(losses[-10:]):.4f}")
    print("OK — loss decreases at every asynchrony level with tuned momentum")


if __name__ == "__main__":
    main()
