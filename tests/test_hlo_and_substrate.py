"""HLO parser, sharding rules, checkpointing, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointing as CK
from repro.data.pipeline import DataConfig, SyntheticImages, SyntheticLM, prefetch
from repro.launch import hlo_parse as HP
from repro.launch.hlo_analysis import Roofline


# ---------------------------------------------------------------------------
# hlo_parse
# ---------------------------------------------------------------------------

def test_trip_count_aware_flops():
    """Scan-over-layers FLOPs must be multiplied by the trip count (XLA's own
    cost_analysis counts while bodies once)."""
    L, B, D = 5, 8, 32

    def step(w, x):
        def body(c, wi):
            return jnp.tanh(c @ wi), None
        y, _ = jax.lax.scan(body, x, w)
        return (y ** 2).sum()

    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    compiled = jax.jit(jax.grad(step)).lower(ws, xs).compile()
    st = HP.analyze_module(compiled.as_text())
    expected = 3 * L * 2 * B * D * D   # fwd dot + 2 bwd dots per layer
    assert abs(st.dot_flops - expected) / expected < 0.05, (
        st.dot_flops, expected)


def test_shape_bytes():
    assert HP._shape_bytes("f32", "2,3") == 24
    assert HP._shape_bytes("bf16", "128") == 256
    assert HP._shape_bytes("s32", "") == 4


def test_split_computations_roundtrip():
    compiled = jax.jit(lambda x: jnp.tanh(x) @ x).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32)).compile()
    comps = HP.split_computations(compiled.as_text())
    assert len(comps) >= 1
    assert any("ENTRY" in compiled.as_text() for _ in [0])


def test_roofline_terms():
    r = Roofline(flops=197e12, hbm_bytes=819e9, collective_bytes=25e9,
                 chips=256)
    assert r.t_compute == pytest.approx(1.0)
    assert r.t_memory == pytest.approx(1.0)
    assert r.t_collective == pytest.approx(0.5)
    assert r.bottleneck in ("compute", "memory")
    assert r.step_time == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    CK.save(tmp_path / "ckpt_0000001", tree, step=7, extra={"note": "x"})
    restored, step = CK.restore(tmp_path / "ckpt_0000001", tree)
    assert step == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(restored["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))
    assert CK.latest(tmp_path).name == "ckpt_0000001"


def test_checkpoint_shape_mismatch(tmp_path):
    CK.save(tmp_path / "ckpt_0000002", {"a": jnp.ones((2,))}, step=1)
    with pytest.raises(ValueError):
        CK.restore(tmp_path / "ckpt_0000002", {"a": jnp.ones((3,))})


@pytest.mark.skipif(jax.device_count() < 8,
                    reason="needs 8 host devices (tests/conftest.py)")
def test_checkpoint_restores_through_target_sharding(tmp_path):
    """Save -> restore under a live ("group","data","mp") mesh: each
    restored leaf lands in the TARGET leaf's sharding (device_put through
    leaf.sharding), not replicated on the default device — resuming an
    mp-sharded Engine run must place shards back on their devices."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_group_mesh

    mesh = make_group_mesh(1, 2, 2)
    sh = NamedSharding(mesh, P(None, "mp"))
    w = jax.device_put(jnp.arange(32.0).reshape(4, 8), sh)
    m = jax.device_put(jnp.zeros((4, 8)), sh)
    CK.save(tmp_path / "ckpt_0000003", {"w": w, "m": m}, step=3)
    restored, step = CK.restore(tmp_path / "ckpt_0000003",
                                {"w": w, "m": m})
    assert step == 3
    r = restored["w"]
    assert r.sharding.is_equivalent_to(sh, r.ndim)
    # genuinely distributed: one (4, 4) mp-shard per device, not a
    # single default-device copy
    assert len(r.addressable_shards) == mesh.devices.size
    assert all(s.data.shape == (4, 4) for s in r.addressable_shards)
    np.testing.assert_array_equal(np.asarray(r), np.asarray(w))
    np.testing.assert_array_equal(np.asarray(restored["m"]), np.asarray(m))


def test_checkpoint_dtype_mismatch_requires_allow_cast(tmp_path):
    """Dtype drift between the saved and resuming run raises; an explicit
    allow_cast=True casts to the target dtype."""
    CK.save(tmp_path / "ckpt_0000004", {"a": jnp.ones((2,), jnp.float32)},
            step=1)
    with pytest.raises(ValueError, match="dtype mismatch"):
        CK.restore(tmp_path / "ckpt_0000004",
                   {"a": jnp.ones((2,), jnp.bfloat16)})
    restored, _ = CK.restore(tmp_path / "ckpt_0000004",
                             {"a": jnp.ones((2,), jnp.bfloat16)},
                             allow_cast=True)
    assert restored["a"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(restored["a"], np.float32),
                                  np.ones((2,), np.float32))


def test_checkpoint_slash_keys_do_not_alias(tmp_path):
    """A dict key containing "/" must not alias a nested path: {"a/b"}
    and {"a": {"b"}} flatten to distinct escaped names and round-trip
    with their own values (pre-fix, np.savez silently kept one)."""
    tree = {"a/b": jnp.arange(2.0), "a": {"b": jnp.arange(3.0)}}
    CK.save(tmp_path / "ckpt_0000005", tree, step=5)
    restored, _ = CK.restore(tmp_path / "ckpt_0000005", tree)
    np.testing.assert_array_equal(np.asarray(restored["a/b"]),
                                  np.arange(2.0, dtype=np.float32))
    np.testing.assert_array_equal(np.asarray(restored["a"]["b"]),
                                  np.arange(3.0, dtype=np.float32))


def test_checkpoint_name_collision_raises(tmp_path):
    """save refuses trees whose distinct leaves flatten to the same name
    (a malformed custom node) instead of letting np.savez keep the last
    write."""
    class Dup:
        def __init__(self, a, b):
            self.a, self.b = a, b

    jax.tree_util.register_pytree_with_keys(
        Dup, lambda d: ((("x", d.a), ("x", d.b)), None),
        lambda aux, kids: Dup(*kids))
    with pytest.raises(ValueError, match="collision"):
        CK.save(tmp_path / "ckpt_0000006",
                Dup(jnp.ones((2,)), jnp.zeros((2,))), step=1)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_synthetic_lm_learnable_and_deterministic():
    cfg = DataConfig(batch_size=4, seq_len=32, vocab_size=128, seed=3)
    b1 = list(SyntheticLM(cfg).batches(2))
    b2 = list(SyntheticLM(cfg).batches(2))
    np.testing.assert_array_equal(np.asarray(b1[0]["tokens"]),
                                  np.asarray(b2[0]["tokens"]))
    # labels are next tokens
    np.testing.assert_array_equal(np.asarray(b1[0]["tokens"][:, 1:]),
                                  np.asarray(b1[0]["labels"][:, :-1]))
    # markov structure: conditional entropy below uniform
    toks = np.concatenate([np.asarray(b["tokens"]).ravel()
                           for b in SyntheticLM(cfg).batches(8)])
    assert toks.max() < 128


def test_synthetic_lm_host_sharding():
    full = DataConfig(batch_size=8, seq_len=16, vocab_size=64, seed=1)
    half = DataConfig(batch_size=8, seq_len=16, vocab_size=64, seed=1,
                      host_index=0, host_count=2)
    b_full = next(iter(SyntheticLM(full).batches(1)))
    b_half = next(iter(SyntheticLM(half).batches(1)))
    assert b_half["tokens"].shape == (4, 16)
    assert b_full["tokens"].shape == (8, 16)


def test_synthetic_images_and_prefetch():
    cfg = DataConfig(batch_size=4, image_size=8, num_classes=3, seed=0)
    it = prefetch(SyntheticImages(cfg).batches(3), depth=2)
    batches = list(it)
    assert len(batches) == 3
    assert batches[0]["images"].shape == (4, 8, 8, 3)
    assert int(batches[0]["labels"].max()) < 3


def test_synthetic_lm_searchsorted_matches_reference_loop():
    """The vectorized inverse-CDF sampler (one searchsorted over the
    offset-flattened cumulative rows per timestep) reproduces the old
    per-timestep gather+cumsum+compare loop token for token."""
    cfg = DataConfig(batch_size=8, seq_len=24, vocab_size=96, seed=11)
    lm = SyntheticLM(cfg)
    got = next(iter(lm.batches(1)))

    # the seed repo's sampling loop, verbatim
    local = cfg.batch_size
    rng = np.random.default_rng((cfg.seed, cfg.host_index, 1))
    toks = np.empty((local, cfg.seq_len + 1), dtype=np.int32)
    toks[:, 0] = rng.integers(lm._v_eff, size=local)
    for t in range(cfg.seq_len):
        p = lm._trans[toks[:, t]]
        c = p.cumsum(axis=-1)
        u = rng.random((local, 1))
        toks[:, t + 1] = (u > c).sum(axis=-1)
    np.testing.assert_array_equal(got["tokens"], toks[:, :-1])
    np.testing.assert_array_equal(got["labels"], toks[:, 1:])


def test_pipeline_host_to_device_contract():
    """Generators yield HOST numpy batches; ``prefetch`` performs the one
    ``device_put``. (Before this was pinned, generators returned jnp
    arrays and the device_put inside prefetch was a no-op.)"""
    lm_cfg = DataConfig(batch_size=4, seq_len=8, vocab_size=32, seed=0)
    im_cfg = DataConfig(batch_size=4, image_size=8, num_classes=3, seed=0)
    for gen in (SyntheticLM(lm_cfg).batches(2),
                SyntheticImages(im_cfg).batches(2)):
        raw = next(iter(gen))
        for leaf in raw.values():
            assert isinstance(leaf, np.ndarray), type(leaf)
            assert not isinstance(leaf, jax.Array)
    for leaf in jax.tree.leaves(
            next(iter(prefetch(SyntheticImages(im_cfg).batches(1))))):
        assert isinstance(leaf, jax.Array)


def test_prefetch_runs_ahead_of_consumption():
    """depth batches are device_put before the consumer takes the first
    one — the transfer overlap the pipeline exists for."""
    puts = []

    def gen():
        for i in range(4):
            puts.append(f"gen{i}")
            yield {"x": np.full((2,), i, np.float32)}

    it = prefetch(gen(), depth=2)
    first = next(it)
    # generator has been pulled depth+1 = 3 times before the first yield
    assert puts == ["gen0", "gen1", "gen2"]
    assert float(first["x"][0]) == 0.0
    assert len(list(it)) == 3
