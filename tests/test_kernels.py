"""Pallas kernel validation (interpret=True on CPU): shape/dtype sweeps +
property tests against the pure-jnp oracles in ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests report as skipped; rest run
    st = None

from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.lowering_conv import ops as lc_ops
from repro.kernels.lowering_conv import ref as lc_ref
from repro.kernels.lowering_conv import vmem_bytes


# ---------------------------------------------------------------------------
# lowering_conv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape,kern,stride", [
    ((4, 12, 12, 3), (3, 3, 3, 8), 1),
    ((2, 16, 16, 4), (5, 5, 4, 8), 1),
    ((4, 13, 13, 2), (3, 3, 2, 16), 2),
    ((1, 28, 28, 1), (5, 5, 1, 20), 1),     # LeNet conv1
    ((2, 31, 31, 3), (11, 11, 3, 16), 4),   # CaffeNet conv1 geometry
])
def test_lowering_conv_sweep(shape, kern, stride, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), kern).astype(dtype)
    ref = lc_ref.conv_ref(x.astype(jnp.float32), w.astype(jnp.float32), stride)
    out = lc_ops.lowering_conv(x, w, stride=stride, bp=2, rb=4, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bp,rb", [(1, 1), (1, 4), (2, 2), (4, 8), (8, 8)])
def test_lowering_conv_block_sizes(bp, rb):
    """The paper's b_p sweep (Fig. 4): every block size computes the same
    function; only the footprint/efficiency changes."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 10, 10, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 8))
    ref = lc_ref.conv_ref(x, w, 1)
    out = lc_ops.lowering_conv(x, w, stride=1, bp=bp, rb=rb, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_lowering_matches_three_phase_ref():
    """Kernel implements the paper's lowering/GEMM/lifting algorithm."""
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 9, 9, 2))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 2, 4))
    a = lc_ref.lowered_conv_ref(x, w, 1)
    b = lc_ops.lowering_conv(x, w, stride=1, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_vmem_model_linear_in_bp():
    """Fig. 4(c): footprint grows linearly with b_p."""
    kw = dict(rb=4, h=16, w=16, cin=8, kh=3, kw=3, cout=32)
    m1 = vmem_bytes(bp=1, **kw)
    m2 = vmem_bytes(bp=2, **kw)
    m4 = vmem_bytes(bp=4, **kw)
    assert abs((m4 - m2) - 2 * (m2 - m1)) < 1e-6 * m4


if st is None:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_lowering_conv_property():
        pass
else:
    @settings(max_examples=10, deadline=None)
    @given(b=st.integers(1, 4), hw=st.sampled_from([8, 11, 14]),
           k=st.sampled_from([1, 3]), cin=st.integers(1, 4),
           cout=st.sampled_from([4, 8]), seed=st.integers(0, 2**30))
    def test_lowering_conv_property(b, hw, k, cin, cout, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        x = jax.random.normal(ks[0], (b, hw, hw, cin))
        w = jax.random.normal(ks[1], (k, k, cin, cout))
        ref = lc_ref.conv_ref(x, w, 1)
        out = lc_ops.lowering_conv(x, w, stride=1, bp=2, rb=3, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,h,kv,hd,window", [
    (64, 4, 4, 16, None),
    (64, 4, 2, 16, None),      # GQA
    (128, 2, 1, 32, None),     # MQA
    (64, 4, 2, 16, 16),        # sliding window
    (96, 2, 2, 64, 32),
])
def test_flash_attention_sweep(s, h, kv, hd, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, s, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (2, s, kv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (2, s, kv, hd)).astype(dtype)
    rep = h // kv
    ref = fa_ref.attention_ref(
        jnp.repeat(q, 1, 2).astype(jnp.float32),
        jnp.repeat(k, rep, 2).astype(jnp.float32),
        jnp.repeat(v, rep, 2).astype(jnp.float32),
        causal=True, window=window)
    out = fa_ops.flash_attention(q, k, v, causal=True, window=window,
                                 bq=32, bk=32, interpret=True)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("bq,bk", [(8, 8), (16, 64), (64, 16), (128, 128)])
def test_flash_attention_block_sizes(bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 16))
    k = jax.random.normal(ks[1], (1, 128, 2, 16))
    v = jax.random.normal(ks[2], (1, 128, 2, 16))
    ref = fa_ref.attention_ref(q, k, v, causal=True)
    out = fa_ops.flash_attention(q, k, v, bq=bq, bk=bk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


if st is None:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_flash_attention_property():
        pass
else:
    @settings(max_examples=8, deadline=None)
    @given(s=st.sampled_from([32, 48, 64]), h=st.sampled_from([1, 2]),
           window=st.sampled_from([None, 8, 16]), seed=st.integers(0, 2**30))
    def test_flash_attention_property(s, h, window, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        q = jax.random.normal(ks[0], (1, s, h, 8))
        k = jax.random.normal(ks[1], (1, s, h, 8))
        v = jax.random.normal(ks[2], (1, s, h, 8))
        ref = fa_ref.attention_ref(q, k, v, causal=True, window=window)
        out = fa_ops.flash_attention(q, k, v, window=window, bq=16, bk=16,
                                     interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("h,kv", [(4, 2), (4, 1), (2, 2)])
def test_flash_attention_gqa_no_repeat_bitwise(h, kv):
    """GQA without materializing ``jnp.repeat``: the kernel's
    query-head -> kv-head index mapping must be BITWISE equal to feeding
    it explicitly repeated K/V (same blocks, same reduction order — the
    wrapper only changed which rows the BlockSpec index maps fetch)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (2, 64, h, 16))
    k = jax.random.normal(ks[1], (2, 64, kv, 16))
    v = jax.random.normal(ks[2], (2, 64, kv, 16))
    grouped = fa_ops.flash_attention(q, k, v, causal=True, bq=32, bk=32,
                                     interpret=True)
    rep = h // kv
    repeated = fa_ops.flash_attention(q, jnp.repeat(k, rep, axis=2),
                                      jnp.repeat(v, rep, axis=2),
                                      causal=True, bq=32, bk=32,
                                      interpret=True)
    assert np.array_equal(np.asarray(grouped), np.asarray(repeated))


def test_flash_attention_rejects_indivisible_heads():
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 32, 3, 8))
    k = jax.random.normal(ks[1], (1, 32, 2, 8))
    v = jax.random.normal(ks[2], (1, 32, 2, 8))
    with pytest.raises(ValueError):
        fa_ops.flash_attention(q, k, v, interpret=True)


def test_flash_matches_model_attention_path():
    """models.layers.attention_forward(attn_impl='pallas') path parity."""
    from repro.configs.base import ArchConfig
    from repro.models import layers as L
    cfg = ArchConfig(name="t", arch_type="dense", num_layers=1, d_model=64,
                     num_heads=4, num_kv_heads=2, head_dim=16, d_ff=32,
                     vocab_size=64, compute_dtype="float32", remat=False)
    p = L.init_attention(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 64))
    y_ref, _ = L.attention_forward(p, x, cfg, attn_impl="xla")
    y_pal, _ = L.attention_forward(p, x, cfg, attn_impl="pallas")
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=2e-4, atol=2e-4)
