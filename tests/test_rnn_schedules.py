"""RNN/LSTM workload (paper App. F-F) + LR schedules (App. F-G)."""
import jax
import numpy as np
import pytest

from repro.core.async_sgd import delayed_sgd_run
from repro.core.workload import rnn_classify
from repro.optim import schedules as S


def test_lstm_workload_trains():
    wl = rnn_classify()
    params = wl.init(jax.random.PRNGKey(0))
    batches = wl.sample_batches(jax.random.PRNGKey(1), 150, wl.batch_size)
    _, losses, _ = delayed_sgd_run(wl.loss_fn, params, batches, staleness=0,
                                   lr=0.1, momentum=0.6)
    l = np.asarray(losses)
    assert l[-15:].mean() < 0.6 * l[:15].mean()


def test_lstm_staleness_penalty():
    """More asynchrony (untuned) must not converge faster — Fig. 32's SE
    penalty on recurrent models."""
    wl = rnn_classify()
    params = wl.init(jax.random.PRNGKey(0))
    batches = wl.sample_batches(jax.random.PRNGKey(1), 200, wl.batch_size)
    finals = {}
    for s in (0, 3):
        _, losses, _ = delayed_sgd_run(wl.loss_fn, params, batches,
                                       staleness=s, lr=0.1, momentum=0.6)
        finals[s] = float(np.asarray(losses)[-20:].mean())
    assert finals[3] >= finals[0] - 1e-3


def test_schedules():
    assert S.constant(0.1)(10**6) == 0.1
    sd = S.step_decay(1.0, drop=10, every=100)
    assert sd(99) == 1.0 and sd(100) == pytest.approx(0.1)
    cs = S.cosine(1.0, total_steps=100)
    assert cs(0) == pytest.approx(1.0)
    assert cs(100) == pytest.approx(0.1)
    wu = S.warmup_then(S.constant(1.0), 10)
    assert wu(0) == pytest.approx(0.1)
    assert wu(20) == 1.0
