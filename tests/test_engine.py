"""Unified execution engine: multi-device equivalence suite + plumbing.

The core contract (ISSUE acceptance): the shard_map-based grouped step on
8 forced host CPU devices BIT-matches the engine's single-device
reference (lax.map over the same (g, k) shard structure) at g in
{1, 2, 4}, for both update strategies, uniform and weighted
group_weights. Plus: strategy plugins, the Algorithm-1 Runner protocol,
trace replay through the engine, and telemetry feeding the cluster
calibration path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.async_sgd import make_grouped_train_step
from repro.core.auto_optimizer import algorithm1
from repro.core.compute_groups import group_batch_split
from repro.core.workload import (cnn_classify, init_state, make_runner,
                                 mlp_classify)
from repro.engine import Engine, choose_data_parallel, device_batch_split
from repro.engine.timing import Telemetry

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 host devices (tests/conftest.py forces them in tier-1)")


def _tree_bits_equal(a, b):
    return all(bool(jnp.all(x == y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _run_pair(wl, *, strategy, g, weights=None, sizes=None, steps=3,
              lr=0.05, momentum=0.6, weight_decay=0.0, batch=32):
    """(spmd_state, reference_state) after ``steps`` engine rounds."""
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = wl.sample_batches(jax.random.PRNGKey(1), steps, batch)
    kw = dict(strategy=strategy, num_groups=g, lr=lr, momentum=momentum,
              weight_decay=weight_decay, group_weights=weights,
              micro_sizes=sizes, head_filter=wl.head_filter, donate=False)
    e_spmd = Engine(wl.loss_fn, exec_mode="spmd", **kw)
    e_ref = Engine(wl.loss_fn, exec_mode="reference", num_devices=8, **kw)
    ps, ms = params, mom
    pr, mr = params, mom
    ls = lr_ = None
    for t in range(steps):
        b = jax.tree.map(lambda x: x[t], batches)
        ps, ms, ls = e_spmd.step(ps, ms, b)
        pr, mr, lr_ = e_ref.step(pr, mr, b)
    return (ps, ms, ls), (pr, mr, lr_)


@needs8
@pytest.mark.parametrize("strategy", ["grouped-fused", "grouped-scan"])
@pytest.mark.parametrize("g", [1, 2, 4])
def test_spmd_bitmatches_reference_uniform(strategy, g):
    """shard_map grouped step == lax.map single-device reference, bitwise,
    uniform group weights (MLP workload)."""
    wl = mlp_classify()
    (ps, ms, ls), (pr, mr, lr_) = _run_pair(wl, strategy=strategy, g=g)
    assert _tree_bits_equal(ps, pr), (strategy, g)
    assert _tree_bits_equal(ms, mr), (strategy, g)
    assert float(ls) == float(lr_), (strategy, g)


@needs8
@pytest.mark.parametrize("strategy", ["grouped-fused", "grouped-scan"])
@pytest.mark.parametrize("g", [2, 4])
def test_spmd_bitmatches_reference_weighted(strategy, g):
    """Same, with unequal heterogeneous group weights (share-weighted
    updates from a cluster allocation)."""
    wl = mlp_classify()
    weights = tuple(np.linspace(1.0, 2.0, g))
    (ps, ms, ls), (pr, mr, lr_) = _run_pair(wl, strategy=strategy, g=g,
                                            weights=weights)
    assert _tree_bits_equal(ps, pr), (strategy, g)
    assert _tree_bits_equal(ms, mr), (strategy, g)
    assert float(ls) == float(lr_), (strategy, g)


@needs8
@pytest.mark.parametrize("strategy", ["grouped-fused", "grouped-scan"])
def test_spmd_bitmatches_reference_cnn_head(strategy):
    """The paper's CNN workload with the merged-FC head filter: head
    params take the single averaged update, backbone the g stale updates —
    identical on the mesh and the reference."""
    wl = cnn_classify()
    (ps, ms, ls), (pr, mr, lr_) = _run_pair(wl, strategy=strategy, g=4,
                                            batch=16)
    assert _tree_bits_equal(ps, pr), strategy
    assert _tree_bits_equal(ms, mr), strategy
    assert float(ls) == float(lr_)


@needs8
def test_spmd_bitmatches_reference_sized_microbatches():
    """Ragged heterogeneous allocation: sized wrap-filled microbatches +
    weights, still bitwise across spmd/reference."""
    wl = mlp_classify()
    (ps, ms, _), (pr, mr, _) = _run_pair(
        wl, strategy="grouped-fused", g=2, weights=(0.625, 0.375),
        sizes=(20, 12), batch=32)
    assert _tree_bits_equal(ps, pr)
    assert _tree_bits_equal(ms, mr)


@needs8
@pytest.mark.parametrize("strategy", ["grouped-fused", "grouped-scan"])
def test_spmd_matches_reference_weight_decay_one_ulp(strategy):
    """With weight decay the update's multiply-add may FMA-contract
    differently between the two compiled programs (docs/engine.md):
    everything else pinned bitwise above, this case is pinned to <= 1 ulp
    of fp32."""
    wl = mlp_classify()
    (ps, _, _), (pr, _, _) = _run_pair(wl, strategy=strategy, g=2,
                                       weight_decay=1e-4)
    for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(pr)):
        np.testing.assert_array_almost_equal_nulp(np.asarray(a),
                                                  np.asarray(b), nulp=1)


@needs8
def test_spmd_bitmatches_reference_transformer():
    """Model-agnosticism: the reduced token-LM through the same engine,
    mesh vs reference, bitwise. (Transformer backward is exactly the case
    where vmap-batched grads do NOT bit-match unbatched ones, which is
    what the shard-structured reference exists for.)"""
    from repro.configs import get_smoke_config
    from repro.models import transformer as T

    cfg = get_smoke_config("qwen2-7b")
    class WL:
        @staticmethod
        def init(key):
            return T.init_params(key, cfg)
        @staticmethod
        def loss_fn(p, b):
            return T.lm_loss(p, b, cfg)
        @staticmethod
        def sample_batches(key, steps, batch):
            k1, k2 = jax.random.split(key)
            return {"tokens": jax.random.randint(
                        k1, (steps, batch, 16), 0, cfg.vocab_size),
                    "labels": jax.random.randint(
                        k2, (steps, batch, 16), 0, cfg.vocab_size)}
        head_filter = None

    (ps, ms, ls), (pr, mr, lr_) = _run_pair(WL, strategy="grouped-fused",
                                            g=2, steps=2, batch=8)
    assert _tree_bits_equal(ps, pr)
    assert _tree_bits_equal(ms, mr)
    assert float(ls) == float(lr_)


def test_vmap_mode_is_legacy_step():
    """exec_mode="vmap" reproduces make_grouped_train_step exactly (it IS
    the same step function behind the engine's batch preparation)."""
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = wl.sample_batches(jax.random.PRNGKey(1), 3, 32)
    eng = Engine(wl.loss_fn, strategy="grouped-fused", num_groups=4, lr=0.05,
                 momentum=0.9, exec_mode="vmap", donate=False)
    legacy = jax.jit(make_grouped_train_step(wl.loss_fn, num_groups=4,
                                             lr=0.05, momentum=0.9))
    pe, me = params, mom
    pl, ml = params, mom
    for t in range(3):
        b = jax.tree.map(lambda x: x[t], batches)
        pe, me, le = eng.step(pe, me, b)
        pl, ml, ll = legacy(pl, ml, group_batch_split(b, 4))
    assert _tree_bits_equal(pe, pl)
    assert _tree_bits_equal(me, ml)
    np.testing.assert_allclose(float(le), float(ll), rtol=1e-6)


def test_sync_strategy_pinned_to_g1():
    wl = mlp_classify()
    with pytest.raises(ValueError, match="pinned to g=1"):
        Engine(wl.loss_fn, strategy="sync", num_groups=4)
    runner = Engine(wl.loss_fn, strategy="sync",
                    sample_batches=wl.sample_batches, batch_size=8)
    with pytest.raises(ValueError, match="pinned to g=1"):
        runner((wl.init(jax.random.PRNGKey(0)), 0), g=2, mu=0.0, eta=0.05,
               steps=2, probe=True)
    eng = Engine(wl.loss_fn, strategy="sync", num_groups=1, lr=0.05,
                 momentum=0.6, donate=False)
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = wl.sample_batches(jax.random.PRNGKey(1), 3, 32)
    p = params
    for t in range(3):
        b = jax.tree.map(lambda x: x[t], batches)
        p, mom, loss = eng.step(p, mom, b)
    assert np.isfinite(float(loss))


def test_engine_is_algorithm1_runner():
    """make_runner returns an Engine; Algorithm 1 drives it end-to-end —
    no per-caller training loop left between them."""
    wl = mlp_classify()
    runner = make_runner(wl, seed=0)
    assert isinstance(runner, Engine)
    state = init_state(wl, seed=0)
    res = algorithm1(runner, state, n_devices=8, epochs=1, epoch_steps=40,
                     probe_steps=15, g0=2)
    assert res.losses[-10:].mean() < res.losses[:10].mean()


def test_engine_runner_probe_semantics():
    """Probe runs restart from the same checkpoint: state unchanged, same
    key schedule as the historical closure-based runner."""
    wl = mlp_classify()
    runner = make_runner(wl, seed=0)
    state = init_state(wl, seed=0)
    s1, l1 = runner(state, g=2, mu=0.3, eta=0.05, steps=10, probe=True)
    s2, l2 = runner(state, g=2, mu=0.3, eta=0.05, steps=10, probe=True)
    assert s1 is state and s2 is state
    np.testing.assert_array_equal(l1, l2)
    s3, _ = runner(state, g=2, mu=0.3, eta=0.05, steps=10, probe=False)
    assert s3[1] == 10


def test_grouped_runner_strategy_trains():
    """The deployable grouped step as the Runner substrate (the SPMD mesh
    engages automatically when enough devices are visible)."""
    wl = mlp_classify()
    runner = make_runner(wl, seed=0, strategy="grouped-fused")
    state = init_state(wl, seed=0)
    (final, t0), losses = runner(state, g=4, mu=0.3, eta=0.05, steps=30,
                                 probe=False)
    assert t0 == 30
    assert losses[-5:].mean() < losses[:5].mean()


def test_trace_replay_strategy_matches_direct_replay():
    """Engine(strategy="trace-replay") == repro.exec.replay_trace on the
    same trace/batches — _replay_main's old body, now a strategy."""
    from repro.core import queue_sim
    from repro.exec import replay_trace

    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    T = 12
    _, trace = queue_sim.simulate(g=3, t_conv=1.0, t_fc=0.05, iters=T,
                                  exponential=True, seed=3, return_trace=True)
    batches = wl.sample_batches(jax.random.PRNGKey(1), T, wl.batch_size)
    eng = Engine(wl.loss_fn, strategy="trace-replay", trace=trace, lr=0.05,
                 momentum=0.3, replay_impl="scan")
    it = (jax.tree.map(lambda x: x[t], batches) for t in range(T))
    pf, _, losses = eng.run(params, None, it, steps=T)
    pf2, losses2, _ = replay_trace(wl.loss_fn, params, batches, trace,
                                   lr=0.05, momentum=0.3, impl="scan")
    assert _tree_bits_equal(pf, pf2)
    np.testing.assert_allclose(losses, np.asarray(losses2), rtol=1e-6)


def test_trace_replay_requires_trace_and_rejects_runner():
    wl = mlp_classify()
    eng = Engine(wl.loss_fn, strategy="trace-replay")
    with pytest.raises(ValueError, match="trace"):
        eng.run(wl.init(jax.random.PRNGKey(0)), None, iter([]), steps=4)
    with pytest.raises(ValueError, match="Runner"):
        eng((None, 0), g=1, mu=0.0, eta=0.1, steps=1, probe=True)


def test_telemetry_feeds_cluster_calibration():
    """Engine telemetry -> black-box DeviceSpec throughput (the planner
    calibration path) without a separate probe run."""
    from repro.cluster import DeviceSpec, spec_from_telemetry

    wl = mlp_classify()
    eng = Engine(wl.loss_fn, num_groups=2, lr=0.05, donate=False)
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = wl.sample_batches(jax.random.PRNGKey(1), 4, 32)
    it = (jax.tree.map(lambda x: x[t], batches) for t in range(4))
    eng.run(params, mom, it, steps=4)
    assert len(eng.telemetry) == 4
    assert eng.telemetry.median_step_s() > 0
    spec = spec_from_telemetry(
        DeviceSpec("probe", "cpu", peak_flops=1e12, mem_bw=1e11,
                   net_bw=1e9),
        eng.telemetry, batch_size=32)
    assert spec.throughput == eng.telemetry.throughput(32)
    assert spec.predict_throughput() == spec.throughput
    # profile(): the cluster probe contract against the engine's own step
    b0 = jax.tree.map(lambda x: x[0], batches)
    thr = eng.profile(params, mom, b0, warmup=1, iters=3)
    assert thr > 0


def test_telemetry_stats():
    t = Telemetry(skip=1)
    with pytest.raises(ValueError):
        t.median_step_s()
    for s in (5.0, 0.2, 0.4, 0.3):     # first (compile) step skipped
        t.record(step_s=s, data_s=0.01)
    assert t.median_step_s() == 0.3
    assert abs(t.throughput(30) - 100.0) < 1e-9
    s = t.summary(batch_size=30)
    assert s["steps"] == 4 and "examples_per_s" in s


def test_choose_data_parallel_and_device_split():
    assert choose_data_parallel(16, 4) == 4
    assert choose_data_parallel(10, 4) == 2   # largest divisor of 10 <= 4
    assert choose_data_parallel(7, 4) == 1
    assert choose_data_parallel(0, 4) == 1
    gb = {"x": jnp.zeros((2, 6, 3))}
    db = device_batch_split(gb, 2)
    assert db["x"].shape == (2, 2, 3, 3)
    with pytest.raises(ValueError, match="not divisible"):
        device_batch_split(gb, 4)


def test_reference_mode_needs_no_devices():
    """The reference twin runs on one device regardless of the visible
    pool — num_devices only shapes the (g, k) structure it mirrors."""
    wl = mlp_classify()
    eng = Engine(wl.loss_fn, num_groups=4, lr=0.05, exec_mode="reference",
                 num_devices=1)
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    b = jax.tree.map(lambda x: x[0],
                     wl.sample_batches(jax.random.PRNGKey(1), 1, 32))
    _, _, loss = eng.step(params, mom, b)
    assert np.isfinite(float(loss))
    built = next(iter(eng._steps.values()))
    assert built.mode == "reference" and built.k == 1


def test_step_never_donates_caller_buffers():
    """Engine.step must leave the caller's arrays alive even with the
    engine's donating run-loop configuration (donate=True default)."""
    wl = mlp_classify()
    eng = Engine(wl.loss_fn, num_groups=2, lr=0.05)   # donate=True default
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    b = jax.tree.map(lambda x: x[0],
                     wl.sample_batches(jax.random.PRNGKey(1), 1, 32))
    eng.step(params, mom, b)
    # original buffers still usable after the step
    assert np.isfinite(float(wl.loss_fn(params, b)))
    # and run() protects them too (copy-in before its donating loop)
    it = (jax.tree.map(lambda x: x[t],
                       wl.sample_batches(jax.random.PRNGKey(2), 3, 32))
          for t in range(3))
    eng.run(params, mom, it, steps=3)
    assert np.isfinite(float(wl.loss_fn(params, b)))


def test_engine_describe_and_spec():
    wl = mlp_classify()
    eng = Engine(wl.loss_fn, num_groups=4)
    spec = eng.group_spec()
    assert spec.staleness == 3
    d = eng.describe(4, 8)
    assert "g=4" in d and "S=3" in d
