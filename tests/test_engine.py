"""Unified execution engine: multi-device equivalence suite + plumbing.

The core contract (ISSUE acceptance): the shard_map-based grouped step on
8 forced host CPU devices BIT-matches the engine's single-device
reference (lax.map over the same (g, k) shard structure) at g in
{1, 2, 4}, for both update strategies, uniform and weighted
group_weights. Plus: strategy plugins, the Algorithm-1 Runner protocol,
trace replay through the engine, and telemetry feeding the cluster
calibration path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.async_sgd import make_grouped_train_step
from repro.core.auto_optimizer import algorithm1
from repro.core.compute_groups import group_batch_split
from repro.core.workload import (cnn_classify, init_state, make_runner,
                                 mlp_classify)
from repro.engine import (Engine, StrandedDevicesWarning, assign_buckets,
                          choose_data_parallel, device_batch_split)
from repro.engine.buckets import pack_bucket, unpack_bucket
from repro.engine.timing import Telemetry

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 host devices (tests/conftest.py forces them in tier-1)")


def _tree_bits_equal(a, b):
    return all(bool(jnp.all(x == y))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def _run_pair(wl, *, strategy, g, weights=None, sizes=None, steps=3,
              lr=0.05, momentum=0.6, weight_decay=0.0, batch=32,
              **engine_kw):
    """(spmd_state, reference_state) after ``steps`` engine rounds."""
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = wl.sample_batches(jax.random.PRNGKey(1), steps, batch)
    kw = dict(strategy=strategy, num_groups=g, lr=lr, momentum=momentum,
              weight_decay=weight_decay, group_weights=weights,
              micro_sizes=sizes, head_filter=wl.head_filter, donate=False,
              **engine_kw)
    e_spmd = Engine(wl.loss_fn, exec_mode="spmd", **kw)
    e_ref = Engine(wl.loss_fn, exec_mode="reference", num_devices=8, **kw)
    ps, ms = params, mom
    pr, mr = params, mom
    ls = lr_ = None
    for t in range(steps):
        b = jax.tree.map(lambda x: x[t], batches)
        ps, ms, ls = e_spmd.step(ps, ms, b)
        pr, mr, lr_ = e_ref.step(pr, mr, b)
    return (ps, ms, ls), (pr, mr, lr_)


@needs8
@pytest.mark.parametrize("strategy", ["grouped-fused", "grouped-scan"])
@pytest.mark.parametrize("g", [1, 2, 4])
def test_spmd_bitmatches_reference_uniform(strategy, g):
    """shard_map grouped step == lax.map single-device reference, bitwise,
    uniform group weights (MLP workload)."""
    wl = mlp_classify()
    (ps, ms, ls), (pr, mr, lr_) = _run_pair(wl, strategy=strategy, g=g)
    assert _tree_bits_equal(ps, pr), (strategy, g)
    assert _tree_bits_equal(ms, mr), (strategy, g)
    assert float(ls) == float(lr_), (strategy, g)


@needs8
@pytest.mark.parametrize("strategy", ["grouped-fused", "grouped-scan"])
@pytest.mark.parametrize("g", [2, 4])
def test_spmd_bitmatches_reference_weighted(strategy, g):
    """Same, with unequal heterogeneous group weights (share-weighted
    updates from a cluster allocation)."""
    wl = mlp_classify()
    weights = tuple(np.linspace(1.0, 2.0, g))
    (ps, ms, ls), (pr, mr, lr_) = _run_pair(wl, strategy=strategy, g=g,
                                            weights=weights)
    assert _tree_bits_equal(ps, pr), (strategy, g)
    assert _tree_bits_equal(ms, mr), (strategy, g)
    assert float(ls) == float(lr_), (strategy, g)


@needs8
@pytest.mark.parametrize("strategy", ["grouped-fused", "grouped-scan"])
def test_spmd_bitmatches_reference_cnn_head(strategy):
    """The paper's CNN workload with the merged-FC head filter: head
    params take the single averaged update, backbone the g stale updates —
    identical on the mesh and the reference."""
    wl = cnn_classify()
    (ps, ms, ls), (pr, mr, lr_) = _run_pair(wl, strategy=strategy, g=4,
                                            batch=16)
    assert _tree_bits_equal(ps, pr), strategy
    assert _tree_bits_equal(ms, mr), strategy
    assert float(ls) == float(lr_)


@needs8
def test_spmd_bitmatches_reference_sized_microbatches():
    """Ragged heterogeneous allocation: sized wrap-filled microbatches +
    weights, still bitwise across spmd/reference."""
    wl = mlp_classify()
    (ps, ms, _), (pr, mr, _) = _run_pair(
        wl, strategy="grouped-fused", g=2, weights=(0.625, 0.375),
        sizes=(20, 12), batch=32)
    assert _tree_bits_equal(ps, pr)
    assert _tree_bits_equal(ms, mr)


@needs8
@pytest.mark.parametrize("strategy", ["grouped-fused", "grouped-scan"])
def test_spmd_matches_reference_weight_decay_one_ulp(strategy):
    """With weight decay the update's multiply-add may FMA-contract
    differently between the two compiled programs (docs/engine.md):
    everything else pinned bitwise above, this case is pinned to <= 1 ulp
    of fp32."""
    wl = mlp_classify()
    (ps, _, _), (pr, _, _) = _run_pair(wl, strategy=strategy, g=2,
                                       weight_decay=1e-4)
    for a, b in zip(jax.tree.leaves(ps), jax.tree.leaves(pr)):
        np.testing.assert_array_almost_equal_nulp(np.asarray(a),
                                                  np.asarray(b), nulp=1)


@needs8
def test_spmd_bitmatches_reference_transformer():
    """Model-agnosticism: the reduced token-LM through the same engine,
    mesh vs reference, bitwise. (Transformer backward is exactly the case
    where vmap-batched grads do NOT bit-match unbatched ones, which is
    what the shard-structured reference exists for.)"""
    from repro.configs import get_smoke_config
    from repro.models import transformer as T

    cfg = get_smoke_config("qwen2-7b")
    class WL:
        @staticmethod
        def init(key):
            return T.init_params(key, cfg)
        @staticmethod
        def loss_fn(p, b):
            return T.lm_loss(p, b, cfg)
        @staticmethod
        def sample_batches(key, steps, batch):
            k1, k2 = jax.random.split(key)
            return {"tokens": jax.random.randint(
                        k1, (steps, batch, 16), 0, cfg.vocab_size),
                    "labels": jax.random.randint(
                        k2, (steps, batch, 16), 0, cfg.vocab_size)}
        head_filter = None

    (ps, ms, ls), (pr, mr, lr_) = _run_pair(WL, strategy="grouped-fused",
                                            g=2, steps=2, batch=8)
    assert _tree_bits_equal(ps, pr)
    assert _tree_bits_equal(ms, mr)
    assert float(ls) == float(lr_)


def _mp_sharded(leaf):
    """True if ``leaf``'s committed sharding splits any dim over "mp"."""
    spec = getattr(getattr(leaf, "sharding", None), "spec", None) or ()
    return any(e == "mp" or (isinstance(e, tuple) and "mp" in e)
               for e in spec if e is not None)


@needs8
@pytest.mark.parametrize("strategy", ["grouped-fused", "grouped-scan"])
@pytest.mark.parametrize("g,mp", [(1, 1), (1, 2), (2, 1), (2, 2)])
def test_spmd_bitmatches_reference_model_parallel(strategy, g, mp):
    """ISSUE acceptance: with params/momentum STORED sharded over the
    third ("mp") mesh axis — gathered whole inside the step, grads
    sliced back to the local shard before the update — the grouped step
    still BIT-matches the unsharded single-device reference at
    (g, mp) in {1,2}x{1,2}, both update strategies. all_gather moves
    bits, the elementwise update commutes with slicing; nothing in the
    math may change."""
    wl = mlp_classify()
    (ps, ms, ls), (pr, mr, lr_) = _run_pair(wl, strategy=strategy, g=g,
                                            mp=mp)
    assert _tree_bits_equal(ps, pr), (strategy, g, mp)
    assert _tree_bits_equal(ms, mr), (strategy, g, mp)
    assert float(ls) == float(lr_), (strategy, g, mp)
    if mp > 1:
        # the storage really is model-parallel, not silently replicated
        assert any(_mp_sharded(l) for l in jax.tree.leaves(ps)), (strategy, g)
        assert any(_mp_sharded(l) for l in jax.tree.leaves(ms)), (strategy, g)


@needs8
def test_spmd_mp_explicit_rules_bitmatch():
    """User-supplied (path-regex, PartitionSpec) rules override the
    TENSOR_PREF/auto derivation — and stay bitwise-identical to the
    reference (rules choose WHERE bytes live, never what is computed)."""
    from jax.sharding import PartitionSpec as P
    wl = mlp_classify()
    rules = (((r"w1",), P(None, "mp")), ((r"b\d",), P()))
    (ps, ms, ls), (pr, mr, lr_) = _run_pair(
        wl, strategy="grouped-fused", g=2, mp=2, sharding_rules=rules)
    assert _tree_bits_equal(ps, pr)
    assert _tree_bits_equal(ms, mr)
    assert float(ls) == float(lr_)
    assert _mp_sharded(ps["w1"])


@needs8
@pytest.mark.parametrize("bucket_bytes", [1, 1 << 30])
def test_spmd_mp_bucketed_exchange_bitmatches(bucket_bytes):
    """Tentpole edge: the overlapped bucketed exchange buckets by LOCAL
    shard bytes when slabs are mp-sharded — tiny buckets (one local leaf
    per gather) and one huge slab both stay bitwise against the
    reference."""
    wl = mlp_classify()
    (ps, ms, ls), (pr, mr, lr_) = _run_pair(
        wl, strategy="grouped-fused", g=2, mp=2, bucket_bytes=bucket_bytes)
    assert _tree_bits_equal(ps, pr), bucket_bytes
    assert _tree_bits_equal(ms, mr), bucket_bytes
    assert float(ls) == float(lr_), bucket_bytes


@needs8
def test_engine_mp_validation():
    """mp plumbing guard-rails: vmap mode cannot shard storage; the
    device budget accounts for g*mp; describe() reports the 3-axis mesh."""
    wl = mlp_classify()
    with pytest.raises(ValueError, match="vmap"):
        Engine(wl.loss_fn, num_groups=2, mp=2, exec_mode="vmap")
    with pytest.raises(ValueError, match="mp"):
        Engine(wl.loss_fn, num_groups=2, mp=0)
    eng = Engine(wl.loss_fn, num_groups=2, mp=2, exec_mode="spmd",
                 donate=False)
    assert "2x2x2" in eng.describe(2, 8) or "mp" in eng.describe(2, 8)


@needs8
@pytest.mark.parametrize("strategy", ["grouped-fused", "grouped-scan"])
@pytest.mark.parametrize("bucket_bytes", [1, 1 << 30])
@pytest.mark.parametrize("g", [1, 2, 4])
def test_spmd_bitmatches_reference_bucket_sizes(strategy, bucket_bytes, g):
    """The overlapped bucketed exchange is bitwise-invariant to the bucket
    plan: tiny buckets (one leaf per gather) and one huge slab both
    bit-match the reference — bucketing reorders independent gathers and
    packs bits unchanged, nothing more."""
    wl = mlp_classify()
    (ps, ms, ls), (pr, mr, lr_) = _run_pair(wl, strategy=strategy, g=g,
                                            bucket_bytes=bucket_bytes)
    assert _tree_bits_equal(ps, pr), (strategy, bucket_bytes, g)
    assert _tree_bits_equal(ms, mr), (strategy, bucket_bytes, g)
    assert float(ls) == float(lr_), (strategy, bucket_bytes, g)


@needs8
@pytest.mark.parametrize("g", [2, 4])
def test_spmd_losses_bitmatch_per_shard(g):
    """The single two-axis loss gather returns the same (g, k) per-shard
    loss board, bit for bit, as the reference's shard-ordered losses (the
    old nested data+group gather pair, collapsed to one collective)."""
    from repro.engine import (make_reference_grouped_step,
                              make_spmd_grouped_step)
    from repro.launch.mesh import make_group_mesh

    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batch = jax.tree.map(lambda x: x[0],
                         wl.sample_batches(jax.random.PRNGKey(1), 1, 32))
    k = 8 // g
    gb = jax.tree.map(
        lambda t: t.reshape((g, t.shape[0] // g) + t.shape[1:]), batch)
    db = device_batch_split(gb, k)
    spmd = make_spmd_grouped_step(wl.loss_fn, make_group_mesh(g, k),
                                  lr=0.05, momentum=0.6)
    ref = make_reference_grouped_step(wl.loss_fn, g, k, lr=0.05,
                                      momentum=0.6)
    _, _, ls = jax.jit(spmd)(params, mom, db)
    _, _, lr_ = jax.jit(ref)(params, mom, db)
    assert ls.shape == (g, k) and lr_.shape == (g, k)
    assert np.asarray(ls).tobytes() == np.asarray(lr_).tobytes()


@needs8
@pytest.mark.parametrize("strategy", ["grouped-fused", "grouped-scan"])
def test_donating_step_hlo_has_no_param_copies(strategy):
    """Donation audit (the run-loop configuration): the compiled donating
    SPMD step aliases every params/momentum input to an output and
    contains no parameter-sized copy instruction — the in-place update
    actually happens in place."""
    import re

    wl = mlp_classify()
    eng = Engine(wl.loss_fn, strategy=strategy, num_groups=2, lr=0.05,
                 momentum=0.6, exec_mode="spmd")   # donate=True default
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batch = jax.tree.map(lambda x: x[0],
                         wl.sample_batches(jax.random.PRNGKey(1), 1, 32))
    built = eng._built_step(eng.strategy, g=2, lr=0.05, momentum=0.6,
                            per_group_batch=16)
    assert built.donating
    txt = built.fn.lower(params, mom, built.prepare(batch)) \
        .compile().as_text()
    n_state = len(jax.tree.leaves(params)) + len(jax.tree.leaves(mom))
    header = txt.splitlines()[0]
    assert "input_output_alias" in header
    aliased = re.findall(r"\{(\d+)\}: \(\d+, \{\}", header)
    assert len(aliased) >= n_state, header
    param_shapes = {tuple(l.shape) for l in jax.tree.leaves(params)}
    copies = []
    for line in txt.splitlines():
        m = re.search(r"= f32\[([\d,]*)\][^ ]* copy\(", line)
        if m:
            shp = (tuple(int(x) for x in m.group(1).split(","))
                   if m.group(1) else ())
            if shp in param_shapes:
                copies.append(line.strip())
    assert not copies, copies


def test_run_then_step_reuses_compile():
    """donate is not part of the compile-cache key: run() (donating),
    step() and profile() (both buffer-protected) on the same config share
    ONE built step instead of re-jitting."""
    wl = mlp_classify()
    eng = Engine(wl.loss_fn, num_groups=2, lr=0.05)   # donate=True default
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = wl.sample_batches(jax.random.PRNGKey(1), 3, 32)
    it = (jax.tree.map(lambda x: x[t], batches) for t in range(3))
    eng.run(params, mom, it, steps=3)
    assert len(eng._steps) == 1
    b0 = jax.tree.map(lambda x: x[0], batches)
    eng.step(params, mom, b0)
    eng.profile(params, mom, b0, warmup=1, iters=2)
    assert len(eng._steps) == 1      # still the single shared compile
    # the caller's buffers survived both protected entries
    assert np.isfinite(float(wl.loss_fn(params, b0)))


def test_bucket_assignment_and_packing():
    """assign_buckets packs reverse flatten order (backward production
    order), splits on dtype/head class and size target; pack/unpack is a
    bit-exact round trip including a leading gather axis."""
    leaves = [jnp.zeros((32,)), jnp.zeros((4,)),
              jnp.ones((16, 32)), jnp.ones((32, 4))]
    flags = [False] * 4
    tiny = assign_buckets(leaves, flags, 1)
    assert [b.indices for b in tiny] == [(3,), (2,), (1,), (0,)]
    one = assign_buckets(leaves, flags, 1 << 30)
    assert [b.indices for b in one] == [(3, 2, 1, 0)]
    assert one[0].num_elements == sum(l.size for l in leaves)
    # 600-byte target: w2 (512 B), w1 (2048 B), then both biases
    mid = assign_buckets(leaves, flags, 600)
    assert [b.indices for b in mid] == [(3,), (2,), (1, 0)]
    # head leaves never share a slab with backbone leaves
    split = assign_buckets(leaves, [False, False, False, True], 1 << 30)
    assert [(b.indices, b.is_head) for b in split] == \
        [((3,), True), ((2, 1, 0), False)]
    # mixed dtypes split too
    leaves2 = [jnp.zeros((8,), jnp.float32), jnp.zeros((8,), jnp.bfloat16)]
    assert len(assign_buckets(leaves2, [False, False], 1 << 30)) == 2
    # pack -> unpack round trip, with and without a leading (g,) axis
    vals = [jax.random.normal(jax.random.PRNGKey(i), l.shape)
            for i, l in enumerate(leaves)]
    for b in mid:
        slab = pack_bucket(b, vals)
        back = unpack_bucket(b, slab)
        for i, arr in zip(b.indices, back):
            assert np.asarray(arr).tobytes() == np.asarray(vals[i]).tobytes()
        stacked = jnp.stack([slab, slab + 1.0])
        assert unpack_bucket(b, stacked)[0].shape == \
            (2,) + vals[b.indices[0]].shape
    with pytest.raises(ValueError, match="bucket_bytes"):
        assign_buckets(leaves, flags, 0)


def test_choose_data_parallel_warns_on_stranded_devices():
    """Silent k=1 fallback no longer silent: stranding device slots warns
    and lands in engine telemetry."""
    with pytest.warns(StrandedDevicesWarning, match="k=2 < 4"):
        assert choose_data_parallel(10, 4) == 2
    with pytest.warns(StrandedDevicesWarning, match="k=1 < 4"):
        assert choose_data_parallel(7, 4) == 1
    import warnings as W
    with W.catch_warnings():
        W.simplefilter("error")               # full mesh: no warning
        assert choose_data_parallel(16, 4) == 4
        assert choose_data_parallel(10, 4, warn=False) == 2
    if jax.device_count() >= 8:
        wl = mlp_classify()
        eng = Engine(wl.loss_fn, num_groups=2, lr=0.05, donate=False,
                     exec_mode="spmd")
        params = wl.init(jax.random.PRNGKey(0))
        mom = jax.tree.map(jnp.zeros_like, params)
        batch = jax.tree.map(lambda x: x[0],
                             wl.sample_batches(jax.random.PRNGKey(1), 1, 10))
        with pytest.warns(StrandedDevicesWarning):
            eng.step(params, mom, batch)      # per-group batch 5, slots 4
        assert any("stranded" in n for n in eng.telemetry.notes)
        assert "notes" in eng.telemetry.summary()


def test_vmap_mode_is_legacy_step():
    """exec_mode="vmap" reproduces make_grouped_train_step exactly (it IS
    the same step function behind the engine's batch preparation)."""
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = wl.sample_batches(jax.random.PRNGKey(1), 3, 32)
    eng = Engine(wl.loss_fn, strategy="grouped-fused", num_groups=4, lr=0.05,
                 momentum=0.9, exec_mode="vmap", donate=False)
    legacy = jax.jit(make_grouped_train_step(wl.loss_fn, num_groups=4,
                                             lr=0.05, momentum=0.9))
    pe, me = params, mom
    pl, ml = params, mom
    for t in range(3):
        b = jax.tree.map(lambda x: x[t], batches)
        pe, me, le = eng.step(pe, me, b)
        pl, ml, ll = legacy(pl, ml, group_batch_split(b, 4))
    assert _tree_bits_equal(pe, pl)
    assert _tree_bits_equal(me, ml)
    np.testing.assert_allclose(float(le), float(ll), rtol=1e-6)


def test_sync_strategy_pinned_to_g1():
    wl = mlp_classify()
    with pytest.raises(ValueError, match="pinned to g=1"):
        Engine(wl.loss_fn, strategy="sync", num_groups=4)
    runner = Engine(wl.loss_fn, strategy="sync",
                    sample_batches=wl.sample_batches, batch_size=8)
    with pytest.raises(ValueError, match="pinned to g=1"):
        runner((wl.init(jax.random.PRNGKey(0)), 0), g=2, mu=0.0, eta=0.05,
               steps=2, probe=True)
    eng = Engine(wl.loss_fn, strategy="sync", num_groups=1, lr=0.05,
                 momentum=0.6, donate=False)
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = wl.sample_batches(jax.random.PRNGKey(1), 3, 32)
    p = params
    for t in range(3):
        b = jax.tree.map(lambda x: x[t], batches)
        p, mom, loss = eng.step(p, mom, b)
    assert np.isfinite(float(loss))


def test_engine_is_algorithm1_runner():
    """make_runner returns an Engine; Algorithm 1 drives it end-to-end —
    no per-caller training loop left between them."""
    wl = mlp_classify()
    runner = make_runner(wl, seed=0)
    assert isinstance(runner, Engine)
    state = init_state(wl, seed=0)
    res = algorithm1(runner, state, n_devices=8, epochs=1, epoch_steps=40,
                     probe_steps=15, g0=2)
    assert res.losses[-10:].mean() < res.losses[:10].mean()


def test_engine_runner_probe_semantics():
    """Probe runs restart from the same checkpoint: state unchanged, same
    key schedule as the historical closure-based runner."""
    wl = mlp_classify()
    runner = make_runner(wl, seed=0)
    state = init_state(wl, seed=0)
    s1, l1 = runner(state, g=2, mu=0.3, eta=0.05, steps=10, probe=True)
    s2, l2 = runner(state, g=2, mu=0.3, eta=0.05, steps=10, probe=True)
    assert s1 is state and s2 is state
    np.testing.assert_array_equal(l1, l2)
    s3, _ = runner(state, g=2, mu=0.3, eta=0.05, steps=10, probe=False)
    assert s3[1] == 10


def test_grouped_runner_strategy_trains():
    """The deployable grouped step as the Runner substrate (the SPMD mesh
    engages automatically when enough devices are visible)."""
    wl = mlp_classify()
    runner = make_runner(wl, seed=0, strategy="grouped-fused")
    state = init_state(wl, seed=0)
    (final, t0), losses = runner(state, g=4, mu=0.3, eta=0.05, steps=30,
                                 probe=False)
    assert t0 == 30
    assert losses[-5:].mean() < losses[:5].mean()


def test_trace_replay_strategy_matches_direct_replay():
    """Engine(strategy="trace-replay") == repro.exec.replay_trace on the
    same trace/batches — _replay_main's old body, now a strategy."""
    from repro.core import queue_sim
    from repro.exec import replay_trace

    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    T = 12
    _, trace = queue_sim.simulate(g=3, t_conv=1.0, t_fc=0.05, iters=T,
                                  exponential=True, seed=3, return_trace=True)
    batches = wl.sample_batches(jax.random.PRNGKey(1), T, wl.batch_size)
    eng = Engine(wl.loss_fn, strategy="trace-replay", trace=trace, lr=0.05,
                 momentum=0.3, replay_impl="scan")
    it = (jax.tree.map(lambda x: x[t], batches) for t in range(T))
    pf, _, losses = eng.run(params, None, it, steps=T)
    pf2, losses2, _ = replay_trace(wl.loss_fn, params, batches, trace,
                                   lr=0.05, momentum=0.3, impl="scan")
    assert _tree_bits_equal(pf, pf2)
    np.testing.assert_allclose(losses, np.asarray(losses2), rtol=1e-6)


def test_trace_replay_requires_trace_and_rejects_runner():
    wl = mlp_classify()
    eng = Engine(wl.loss_fn, strategy="trace-replay")
    with pytest.raises(ValueError, match="trace"):
        eng.run(wl.init(jax.random.PRNGKey(0)), None, iter([]), steps=4)
    with pytest.raises(ValueError, match="Runner"):
        eng((None, 0), g=1, mu=0.0, eta=0.1, steps=1, probe=True)


def test_telemetry_feeds_cluster_calibration():
    """Engine telemetry -> black-box DeviceSpec throughput (the planner
    calibration path) without a separate probe run."""
    from repro.cluster import DeviceSpec, spec_from_telemetry

    wl = mlp_classify()
    eng = Engine(wl.loss_fn, num_groups=2, lr=0.05, donate=False)
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = wl.sample_batches(jax.random.PRNGKey(1), 4, 32)
    it = (jax.tree.map(lambda x: x[t], batches) for t in range(4))
    eng.run(params, mom, it, steps=4)
    assert len(eng.telemetry) == 4
    assert eng.telemetry.median_step_s() > 0
    spec = spec_from_telemetry(
        DeviceSpec("probe", "cpu", peak_flops=1e12, mem_bw=1e11,
                   net_bw=1e9),
        eng.telemetry, batch_size=32)
    assert spec.throughput == eng.telemetry.throughput(32)
    assert spec.predict_throughput() == spec.throughput
    # profile(): the cluster probe contract against the engine's own step
    b0 = jax.tree.map(lambda x: x[0], batches)
    thr = eng.profile(params, mom, b0, warmup=1, iters=3)
    assert thr > 0


def test_telemetry_stats():
    t = Telemetry(skip=1)
    with pytest.raises(ValueError):
        t.median_step_s()
    for s in (5.0, 0.2, 0.4, 0.3):     # first (compile) step skipped
        t.record(step_s=s, data_s=0.01)
    assert t.median_step_s() == 0.3
    assert abs(t.throughput(30) - 100.0) < 1e-9
    s = t.summary(batch_size=30)
    assert s["steps"] == 4 and "examples_per_s" in s


def test_choose_data_parallel_and_device_split():
    assert choose_data_parallel(16, 4) == 4
    # largest divisor of 10 <= 4 is 2; 7 forces k=1 (warning behaviour is
    # pinned by test_choose_data_parallel_warns_on_stranded_devices)
    assert choose_data_parallel(10, 4, warn=False) == 2
    assert choose_data_parallel(7, 4, warn=False) == 1
    assert choose_data_parallel(0, 4) == 1
    gb = {"x": jnp.zeros((2, 6, 3))}
    db = device_batch_split(gb, 2)
    assert db["x"].shape == (2, 2, 3, 3)
    with pytest.raises(ValueError, match="not divisible"):
        device_batch_split(gb, 4)


def test_reference_mode_needs_no_devices():
    """The reference twin runs on one device regardless of the visible
    pool — num_devices only shapes the (g, k) structure it mirrors."""
    wl = mlp_classify()
    eng = Engine(wl.loss_fn, num_groups=4, lr=0.05, exec_mode="reference",
                 num_devices=1)
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    b = jax.tree.map(lambda x: x[0],
                     wl.sample_batches(jax.random.PRNGKey(1), 1, 32))
    _, _, loss = eng.step(params, mom, b)
    assert np.isfinite(float(loss))
    built = next(iter(eng._steps.values()))
    assert built.mode == "reference" and built.k == 1


def test_step_never_donates_caller_buffers():
    """Engine.step must leave the caller's arrays alive even with the
    engine's donating run-loop configuration (donate=True default)."""
    wl = mlp_classify()
    eng = Engine(wl.loss_fn, num_groups=2, lr=0.05)   # donate=True default
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    b = jax.tree.map(lambda x: x[0],
                     wl.sample_batches(jax.random.PRNGKey(1), 1, 32))
    eng.step(params, mom, b)
    # original buffers still usable after the step
    assert np.isfinite(float(wl.loss_fn(params, b)))
    # and run() protects them too (copy-in before its donating loop)
    it = (jax.tree.map(lambda x: x[t],
                       wl.sample_batches(jax.random.PRNGKey(2), 3, 32))
          for t in range(3))
    eng.run(params, mom, it, steps=3)
    assert np.isfinite(float(wl.loss_fn(params, b)))


def test_engine_describe_and_spec():
    wl = mlp_classify()
    eng = Engine(wl.loss_fn, num_groups=4)
    spec = eng.group_spec()
    assert spec.staleness == 3
    d = eng.describe(4, 8)
    assert "g=4" in d and "S=3" in d
