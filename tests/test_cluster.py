"""Heterogeneous cluster subsystem: black-box profiles, allocator
invariants, sim-reduces-to-queue_sim, planner model vs simulation, and the
share-weighted grouped step."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import cluster
from repro.core import queue_sim
from repro.core.async_sgd import make_grouped_train_step
from repro.core.auto_optimizer import algorithm1
from repro.core.compute_groups import group_batch_split
from repro.core.workload import mlp_classify

MIXED = "8xgpu-g2.2xlarge,8xcpu-c4.4xlarge"
COST = cluster.WorkloadCost(flops_per_example=2e9, bytes_per_example=2e8,
                            grad_bytes=4e6)


# ---------------------------------------------------------------------------
# devices
# ---------------------------------------------------------------------------

def test_parse_cluster_spec():
    devs = cluster.parse_cluster_spec(MIXED)
    assert len(devs) == 16
    assert sum(d.kind == "gpu" for d in devs) == 8
    assert sum(d.kind == "cpu" for d in devs) == 8
    assert cluster.parse_cluster_spec("tpu-v5e")[0].kind == "tpu"
    with pytest.raises(KeyError):
        cluster.parse_cluster_spec("4xno-such-device")
    with pytest.raises(ValueError):
        cluster.parse_cluster_spec("")


def test_measured_throughput_overrides_roofline():
    spec = cluster.get_device("cpu-c4.4xlarge")
    roofline = spec.predict_throughput(COST)
    measured = dataclasses.replace(spec, throughput=123.0)
    assert measured.predict_throughput(COST) == 123.0
    assert roofline != 123.0
    with pytest.raises(ValueError):  # no measurement and no cost
        spec.predict_throughput(None)


def test_profile_device_times_jitted_step():
    """The black-box probe: times an actual jitted step, returns examples/s."""
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    batch = jax.tree.map(lambda x: x[0],
                         wl.sample_batches(jax.random.PRNGKey(1), 1, 32))
    vg = jax.jit(jax.value_and_grad(wl.loss_fn))
    thr = cluster.profile_device(vg, (params, batch), batch_size=32,
                                 warmup=1, iters=3)
    assert thr > 0
    spec = cluster.profiled_spec(
        cluster.DeviceSpec("probe", "cpu", 1e12, 1e11, 1e9),
        vg, (params, batch), batch_size=32, warmup=1, iters=3)
    assert spec.throughput > 0
    assert spec.predict_throughput() == spec.throughput


# ---------------------------------------------------------------------------
# allocator
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g", [1, 2, 3, 5, 8, 16])
def test_allocator_invariants(g):
    """No empty groups; every device used once; shares sum to the global
    batch with >= 1 per group."""
    devs = cluster.parse_cluster_spec(MIXED)
    alloc = cluster.allocate(devs, g, 64, cost=COST)
    assert alloc.num_groups == g
    assert all(len(gr) >= 1 for gr in alloc.groups)
    assert sorted(i for gr in alloc.groups for i in gr) == list(range(16))
    assert sum(alloc.microbatches) == 64
    assert all(b >= 1 for b in alloc.microbatches)
    assert abs(sum(alloc.weights) - 1.0) < 1e-12


def test_allocator_shares_follow_throughput():
    """A strictly faster group must not get a smaller batch share."""
    devs = cluster.parse_cluster_spec("4xgpu-titan-x,4xcpu-c4.4xlarge")
    alloc = cluster.allocate(devs, 2, 32, cost=COST)
    pairs = sorted(zip(alloc.throughputs, alloc.microbatches))
    assert pairs[0][1] <= pairs[1][1]
    with pytest.raises(ValueError):   # batch too small for g groups
        cluster.allocate(devs, 8, 4, cost=COST)
    with pytest.raises(ValueError):   # more groups than devices
        cluster.allocate(devs, 9, 64, cost=COST)


def test_rebalance_shifts_share_to_fast_group():
    devs = cluster.parse_cluster_spec("2xgpu-g2.2xlarge,2xcpu-c4.4xlarge")
    alloc = cluster.allocate(devs, 2, 32, cost=COST)
    # pretend group 0 was observed 3x slower than predicted
    times = [3.0 * alloc.microbatches[0] / alloc.throughputs[0],
             1.0 * alloc.microbatches[1] / alloc.throughputs[1]]
    re = cluster.rebalance(alloc, times)
    assert re.microbatches[0] < alloc.microbatches[0]
    assert re.microbatches[1] > alloc.microbatches[1]
    assert sum(re.microbatches) == 32
    # predicted per-group times equalize at the rebalanced shares
    t0 = re.microbatches[0] / re.throughputs[0]
    t1 = re.microbatches[1] / re.throughputs[1]
    assert abs(t0 - t1) / max(t0, t1) < 0.25   # integer shares: near-equal


# ---------------------------------------------------------------------------
# sim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("exponential", [True, False])
@pytest.mark.parametrize("g", [1, 2, 4, 8])
def test_sim_reduces_to_queue_sim(g, exponential):
    """Identical groups (same seed) => bit-identical to the homogeneous
    simulator."""
    hom = queue_sim.simulate(g=g, t_conv=0.7, t_fc=0.05, iters=1500,
                             exponential=exponential, seed=g)
    het = cluster.simulate_hetero(t_conv=[0.7] * g, t_fc=0.05, iters=1500,
                                  exponential=exponential, seed=g)
    assert het.time_per_iteration == hom.time_per_iteration
    assert het.mean_staleness == hom.mean_staleness
    assert np.array_equal(het.staleness_hist, hom.staleness_hist)


def test_sim_straggler_slows_iteration():
    base = cluster.simulate_hetero(t_conv=[0.5] * 4, t_fc=0.05, iters=2000,
                                   exponential=False)
    slow = cluster.simulate_hetero(t_conv=[0.5] * 4, t_fc=0.05, iters=2000,
                                   exponential=False,
                                   slowdown=[1.0, 1.0, 1.0, 4.0])
    assert slow.time_per_iteration > base.time_per_iteration
    # asynchrony contains the damage: far better than a 4x-sync slowdown
    assert slow.time_per_iteration < 4.0 * base.time_per_iteration


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------

def test_hetero_he_reduces_to_homogeneous_model():
    """Equal group times: max(t_fc, 1/sum(1/(t+t_fc))) == the paper's
    max(t_fc, (t_conv + t_fc)/g)."""
    t_conv, t_fc = 0.8, 0.05
    for g in (1, 2, 4, 8):
        het = cluster.hetero_time_per_iteration([t_conv] * g, t_fc)
        hom = max(t_fc, (t_conv + t_fc) / g)
        assert het == pytest.approx(hom, rel=1e-12)


def test_planner_matches_hetero_sim_within_15pct():
    """Acceptance: mixed 8xGPU+8xCPU plan's analytic time/iteration within
    15% of the discrete-event simulation."""
    devs = cluster.parse_cluster_spec(MIXED)
    plan = cluster.best_allocation(devs, global_batch=64, t_fc=0.002,
                                   cost=COST, mu_star_total=0.9)
    sim = cluster.simulate_hetero(t_conv=plan.group_times, t_fc=0.002,
                                  iters=4000, exponential=False)
    err = abs(sim.time_per_iteration - plan.t_iteration) / plan.t_iteration
    assert err < 0.15, (plan.t_iteration, sim.time_per_iteration)


def test_planner_picks_sync_when_se_dominates():
    """mu* = 0 and a sharp SE curve: any staleness costs more iterations
    than the HE speedup buys, so g = 1 wins even with negligible t_fc."""
    devs = cluster.parse_cluster_spec("8xgpu-g2.2xlarge")
    plan = cluster.best_allocation(devs, global_batch=64, t_fc=1e-6,
                                   cost=COST, mu_star_total=0.0,
                                   se_sharpness=16.0)
    assert plan.g == 1


def test_planner_picks_async_when_fc_saturates():
    """A large serial FC phase throttles sync; with a tolerant mu* the
    planner must pick g > 1 (asynchrony hides t_fc)."""
    devs = cluster.parse_cluster_spec("8xgpu-g2.2xlarge")
    t_sync = cluster.plan_for_g(devs, 1, global_batch=64, t_fc=0.05,
                                cost=COST).t_iteration
    plan = cluster.best_allocation(devs, global_batch=64, t_fc=0.05,
                                   cost=COST, mu_star_total=0.9)
    assert plan.g > 1
    assert plan.t_iteration < t_sync


def test_mp_collective_and_feasibility_terms():
    """Unit pins for the new HE terms: all-gather bytes over the slowest
    link, and the state_bytes/mp <= mem_bytes feasibility rule."""
    devs = [cluster.DeviceSpec("d", "gpu", peak_flops=1e12, mem_bw=1e11,
                               net_bw=1e9, mem_bytes=4e9)]
    assert cluster.mp_collective_time(devs, 1e9, 1) == 0.0
    assert cluster.mp_collective_time(devs, 1e9, 2) == pytest.approx(0.5)
    assert cluster.mp_collective_time(devs, 1e9, 4) == pytest.approx(0.75)
    big = cluster.WorkloadCost(flops_per_example=1.0, bytes_per_example=1.0,
                               grad_bytes=1.0, state_bytes=6e9)
    assert not cluster.mp_feasible(devs, big, 1)
    assert cluster.mp_feasible(devs, big, 2)
    assert cluster.mp_feasible(devs, None, 1)      # no cost: unconstrained
    assert cluster.mp_feasible(devs, COST, 1)      # state_bytes=0: same


def test_plan_for_g_is_mp1_point():
    devs = cluster.parse_cluster_spec(MIXED)
    a = cluster.plan_for_g(devs, 2, global_batch=64, t_fc=0.002, cost=COST)
    b = cluster.plan_for_g_mp(devs, 2, 1, global_batch=64, t_fc=0.002,
                              cost=COST)
    assert (a.g, a.mp) == (2, 1) == (b.g, b.mp)
    assert a.group_times == b.group_times
    assert a.time_score == b.time_score


def test_planner_mp_search_is_memory_driven():
    """The 2-D (g, mp) search: a model whose resident state exceeds one
    device's memory makes every mp=1 point infeasible — the planner
    returns the smallest mp that fits (replication costs throughput, so
    more mp than memory demands never wins). A model that fits keeps
    mp=1."""
    devs = cluster.parse_cluster_spec("8xgpu-g2.2xlarge")   # 4 GB/device
    big = cluster.WorkloadCost(flops_per_example=2e9, bytes_per_example=2e8,
                               grad_bytes=4e6, state_bytes=6e9)
    with pytest.raises(ValueError, match="infeasible"):
        cluster.plan_for_g_mp(devs, 1, 1, global_batch=64, t_fc=0.002,
                              cost=big)
    plan = cluster.best_allocation(devs, global_batch=64, t_fc=0.002,
                                   cost=big, mp_candidates=(1, 2, 4))
    assert plan.mp == 2
    assert "mp=2" in plan.describe()
    small = cluster.best_allocation(devs, global_batch=64, t_fc=0.002,
                                    cost=COST, mp_candidates=(1, 2, 4))
    assert small.mp == 1
    # nothing fits: the search re-raises instead of returning a bad plan
    hopeless = dataclasses.replace(big, state_bytes=1e12)
    with pytest.raises(ValueError, match="no feasible"):
        cluster.best_allocation(devs, global_batch=64, t_fc=0.002,
                                cost=hopeless, mp_candidates=(1, 2, 4))


def test_algorithm1_mp_plan_passthrough():
    """A (g, mp) plan flows through Algorithm 1: mp is validated against
    the device budget, carried on the result, and never re-searched."""
    devs = cluster.parse_cluster_spec("8xgpu-g2.2xlarge")
    big = cluster.WorkloadCost(flops_per_example=2e9, bytes_per_example=2e8,
                               grad_bytes=4e6, state_bytes=6e9)
    plan = cluster.best_allocation(devs, global_batch=64, t_fc=0.002,
                                   cost=big, mp_candidates=(1, 2, 4))

    def runner(state, *, g, mu, eta, steps, probe):
        losses = np.linspace(1.0, 0.1 - 0.05 * mu, steps)
        return state, losses

    res = algorithm1(runner, None, n_devices=8, epochs=1, epoch_steps=10,
                     probe_steps=5, plan=plan)
    assert res.mp == plan.mp == 2
    assert res.g == plan.g
    bad = dataclasses.replace(plan, g=8)         # 8 * mp 2 = 16 > 8 devices
    with pytest.raises(ValueError, match="infeasible"):
        algorithm1(runner, None, n_devices=8, epochs=1, epoch_steps=10,
                   probe_steps=5, plan=bad)


def test_algorithm1_accepts_planner_plan():
    """Initial g comes from the plan (not smallest_saturating_g / N)."""
    devs = cluster.parse_cluster_spec(MIXED)
    plan = cluster.best_allocation(devs, global_batch=64, t_fc=0.002,
                                   cost=COST, mu_star_total=0.9)
    seen = []

    def runner(state, *, g, mu, eta, steps, probe):
        seen.append(g)
        # converging losses, better with higher momentum: no g-halving
        losses = np.linspace(1.0, 0.1 - 0.05 * mu, steps)
        return state, losses

    res = algorithm1(runner, None, n_devices=16, epochs=1, epoch_steps=10,
                     probe_steps=5, plan=plan)
    # after the cold-start (g=1) probes, the first searched g is plan.g
    first_searched = next(g for g in seen if g != 1)
    assert first_searched == plan.g
    assert res.g == plan.g

    bad = dataclasses.replace(plan, g=64)
    with pytest.raises(ValueError):
        algorithm1(runner, None, n_devices=16, epochs=1, epoch_steps=10,
                   probe_steps=5, plan=bad)


# ---------------------------------------------------------------------------
# weighted grouped step + sized batch split
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["fused", "scan"])
@pytest.mark.parametrize("g", [1, 2, 4])
def test_weighted_step_equal_shares_match_exactly(g, strategy):
    """Acceptance: uniform group_weights == the equal-share path, exactly
    (bitwise), for both update strategies."""
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = wl.sample_batches(jax.random.PRNGKey(1), 3, 32)
    base = make_grouped_train_step(wl.loss_fn, num_groups=g, lr=0.05,
                                   momentum=0.9, strategy=strategy)
    weighted = make_grouped_train_step(wl.loss_fn, num_groups=g, lr=0.05,
                                       momentum=0.9, strategy=strategy,
                                       group_weights=(1.0 / g,) * g)
    p1 = p2 = params
    m1 = m2 = mom
    for t in range(3):
        gb = group_batch_split(jax.tree.map(lambda x: x[t], batches), g)
        p1, m1, l1 = base(p1, m1, gb)
        p2, m2, l2 = weighted(p2, m2, gb)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(m1), jax.tree.leaves(m2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("strategy", ["fused", "scan"])
def test_weighted_step_unequal_shares_semantics(strategy):
    """g=2, mu=0, shares (3/4, 1/4): backbone applies -lr*2*w_i per
    sub-step, merged-FC head one -lr*sum(w_i g_i) update."""
    def loss_fn(p, batch):
        return jnp.sum(p["conv"] * batch["x"]) + jnp.sum(p["fc"] * batch["x"])

    def head_filter(path):
        return any(getattr(k, "key", None) == "fc" for k in path)

    lr = 0.1
    params = {"conv": jnp.float32(0.0), "fc": jnp.float32(0.0)}
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = {"x": jnp.array([1.0, 3.0])}        # per-group grads 1, 3
    step = make_grouped_train_step(loss_fn, num_groups=2, lr=lr, momentum=0.0,
                                   head_filter=head_filter, strategy=strategy,
                                   group_weights=(0.75, 0.25))
    p, m, loss = step(params, mom, batches)
    np.testing.assert_allclose(float(p["conv"]),
                               -lr * (2 * 0.75 * 1 + 2 * 0.25 * 3), rtol=1e-6)
    np.testing.assert_allclose(float(p["fc"]),
                               -lr * (0.75 * 1 + 0.25 * 3), rtol=1e-6)


def test_group_batch_split_sizes():
    b = {"x": jnp.arange(8.0)}
    out = group_batch_split(b, 2, sizes=(5, 3))
    assert out["x"].shape == (2, 5)
    np.testing.assert_array_equal(np.asarray(out["x"][0]), [0, 1, 2, 3, 4])
    # short group wrap-fills from its own slice only
    np.testing.assert_array_equal(np.asarray(out["x"][1]), [5, 6, 7, 5, 6])
    # equal sizes == the plain reshape
    eq = group_batch_split(b, 2, sizes=(4, 4))
    np.testing.assert_array_equal(np.asarray(eq["x"]),
                                  np.arange(8.0).reshape(2, 4))
    with pytest.raises(ValueError):
        group_batch_split(b, 2, sizes=(5, 4))       # sum != B
    with pytest.raises(ValueError):
        group_batch_split(b, 2, sizes=(8, 0))       # empty group
    with pytest.raises(ValueError):
        group_batch_split(b, 3, sizes=(4, 4))       # len != g


def test_planned_weighted_training_descends():
    """End-to-end: plan a mixed cluster, train the MLP at the planned
    allocation (sized split + weighted updates); loss must descend."""
    devs = cluster.parse_cluster_spec("4xgpu-g2.2xlarge,4xcpu-c4.4xlarge")
    wl = mlp_classify()
    plan = cluster.best_allocation(devs, global_batch=wl.batch_size,
                                   t_fc=0.001, cost=COST, mu_star_total=0.9)
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    step = jax.jit(make_grouped_train_step(
        wl.loss_fn, num_groups=plan.g, lr=0.05, momentum=0.3,
        group_weights=plan.weights))
    batches = wl.sample_batches(jax.random.PRNGKey(1), 40, wl.batch_size)
    losses = []
    for t in range(40):
        gb = group_batch_split(jax.tree.map(lambda x: x[t], batches), plan.g,
                               sizes=plan.allocation.microbatches)
        params, mom, loss = step(params, mom, gb)
        losses.append(float(loss))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
