"""Core Omnivore correctness: delayed SGD semantics, grouped step, Theorem 1
implicit momentum, HE model vs discrete-event simulation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hardware_model as hm
from repro.core import queue_sim
from repro.core.async_sgd import delayed_sgd_run, make_grouped_train_step
from repro.core.compute_groups import GroupSpec, group_batch_split
from repro.core.implicit_momentum import (implicit_momentum,
                                          optimal_explicit_momentum)
from repro.core.workload import mlp_classify, quadratic


def _sgd_reference(loss_fn, params, batches, lr, mu):
    """Plain momentum SGD, step by step."""
    flat, tree = jax.tree.flatten(params)
    v = [jnp.zeros_like(f) for f in flat]
    losses = []
    n = jax.tree.leaves(batches)[0].shape[0]
    for t in range(n):
        batch = jax.tree.map(lambda x: x[t], batches)
        l, g = jax.value_and_grad(loss_fn)(tree.unflatten(flat), batch)
        gf = jax.tree.leaves(g)
        v = [mu * vv - lr * gg for vv, gg in zip(v, gf)]
        flat = [f + vv for f, vv in zip(flat, v)]
        losses.append(float(l))
    return tree.unflatten(flat), np.array(losses)


def test_delayed_sgd_zero_staleness_is_sgd():
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    batches = wl.sample_batches(jax.random.PRNGKey(1), 10, wl.batch_size)
    final, losses, _ = delayed_sgd_run(wl.loss_fn, params, batches,
                                       staleness=0, lr=0.05, momentum=0.6)
    ref, ref_losses = _sgd_reference(wl.loss_fn, params, batches, 0.05, 0.6)
    np.testing.assert_allclose(np.asarray(losses), ref_losses, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(final), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_delayed_sgd_staleness_uses_old_params():
    """With staleness S, the gradient at step t must equal grad(W_{t-S})."""
    # 1-D quadratic, no noise: loss = 0.5 w^2, grad = w. Track exactly.
    def loss_fn(p, batch):
        return 0.5 * p["w"] ** 2
    batches = {"dummy": jnp.zeros((6, 1))}
    lr, S = 0.1, 2
    final, _, trace = delayed_sgd_run(loss_fn, {"w": jnp.float32(1.0)},
                                      batches, staleness=S, lr=lr,
                                      record_params=True)
    w = [1.0]
    for t in range(6):
        stale = w[max(0, t - S)]
        w.append(w[-1] - lr * stale)
    np.testing.assert_allclose(np.asarray(trace["w"]), np.array(w[1:]),
                               rtol=1e-6)


def test_grouped_step_g1_equals_sync():
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    batches = wl.sample_batches(jax.random.PRNGKey(1), 4, wl.batch_size)
    step = make_grouped_train_step(wl.loss_fn, num_groups=1, lr=0.05,
                                   momentum=0.9)
    mom = jax.tree.map(jnp.zeros_like, params)
    p, m = params, mom
    for t in range(4):
        batch = jax.tree.map(lambda x: x[t][None], batches)  # g=1 leading axis
        p, m, loss = step(p, m, batch)
    ref, _ = _sgd_reference(wl.loss_fn, params, batches, 0.05, 0.9)
    for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_grouped_step_head_sync():
    """Merged-FC: head params get ONE averaged update per round; backbone
    gets g sequential updates."""
    def loss_fn(p, batch):
        return jnp.sum(p["conv"] * batch["x"]) + jnp.sum(p["fc"] * batch["x"])

    def head_filter(path):
        return any(getattr(k, "key", None) == "fc" for k in path)

    g, lr = 4, 0.1
    params = {"conv": jnp.float32(0.0), "fc": jnp.float32(0.0)}
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = {"x": jnp.arange(1.0, g + 1.0)}     # grads = 1..4 per group
    step = make_grouped_train_step(loss_fn, num_groups=g, lr=lr, momentum=0.0,
                                   head_filter=head_filter)
    p, m, loss = step(params, mom, batches)
    # backbone: sum of the 4 gradients; head: mean of the 4 gradients
    np.testing.assert_allclose(float(p["conv"]), -lr * (1 + 2 + 3 + 4), rtol=1e-6)
    np.testing.assert_allclose(float(p["fc"]), -lr * 2.5, rtol=1e-6)


def test_group_spec_and_split():
    gs = GroupSpec(num_groups=4, num_devices=16)
    assert gs.staleness == 3 and gs.group_size == 4
    assert abs(gs.implicit_momentum - 0.75) < 1e-9
    with pytest.raises(ValueError):
        GroupSpec(num_groups=3, num_devices=16)
    b = group_batch_split({"x": jnp.zeros((8, 5))}, 4)
    assert b["x"].shape == (4, 2, 5)


@pytest.mark.parametrize("g", [2, 4, 8])
def test_theorem1_implicit_momentum(g):
    """Simulate Theorem 1's exact model (memoryless async workers, mu=0) on a
    quadratic; the AR(2) fit of the expected trajectory must recover implicit
    momentum 1 - 1/g (paper Fig. 6 left)."""
    from repro.core.implicit_momentum import async_quadratic_sim, fit_ar2_momentum
    traj = async_quadratic_sim(g=g, eta=0.2, steps=300, runs=2000)
    mu_eff, eta_eff = fit_ar2_momentum(traj[3:])
    mu_th = implicit_momentum(g)
    assert abs(mu_eff - mu_th) < 0.03, (g, mu_eff, mu_th)
    assert abs(eta_eff - 0.2 / g) < 0.02, (g, eta_eff)


def test_delayed_sgd_staleness_slows_convergence():
    """Sanity on the SPMD-semantics object: more staleness (mu=0) must not
    converge faster on a smooth problem; and tuning mu down compensates."""
    wl = quadratic(dim=8, cond=3.0, noise=0.0)
    params = wl.init(jax.random.PRNGKey(0))
    batches = wl.sample_batches(jax.random.PRNGKey(1), 200, 1)
    final = {}
    for S in (0, 7):
        _, losses, _ = delayed_sgd_run(wl.loss_fn, params, batches,
                                       staleness=S, lr=0.3, momentum=0.0)
        final[S] = float(np.asarray(losses)[-10:].mean())
    assert final[7] >= final[0] - 1e-6


def test_optimal_explicit_momentum():
    assert optimal_explicit_momentum(1, 0.9) == pytest.approx(0.9)
    assert optimal_explicit_momentum(2, 0.9) == pytest.approx(0.8)
    assert optimal_explicit_momentum(16, 0.9) == 0.0  # implicit exceeds opt


def test_he_model_matches_queue_sim():
    """Analytic HE(g) vs discrete-event simulation (paper Fig. 5b)."""
    ph = hm.PhaseTimes(t_conv_compute_1=1.0, t_fc=0.05, conv_grad_bytes=0.0)
    for g in (1, 2, 4, 8, 16):
        pred = hm.he_time_per_iteration(g, 16, ph)
        sim = queue_sim.simulate(g=g, t_conv=1.0 / (16 // g), t_fc=0.05,
                                 iters=4000, exponential=False)
        assert abs(sim.time_per_iteration - pred) / pred < 0.15, (
            g, pred, sim.time_per_iteration)


def test_he_saturation_regimes():
    ph = hm.PhaseTimes(t_conv_compute_1=1.0, t_fc=0.2, conv_grad_bytes=0.0)
    # g large enough -> FC-saturated: time == t_fc
    assert hm.he_time_per_iteration(16, 16, ph) == pytest.approx(0.2)
    # sync: (t_conv(16) + t_fc) / 1
    assert hm.he_time_per_iteration(1, 16, ph) == pytest.approx(1.0 / 16 + 0.2)
    assert hm.smallest_saturating_g(16, ph) in (2, 4)


def test_queue_sim_staleness_mean():
    """Mean staleness ~= g - 1 (round-robin regime, paper §IV-A)."""
    for g in (2, 4, 8):
        r = queue_sim.simulate(g=g, t_conv=1.0, t_fc=0.01, iters=3000,
                               exponential=True)
        assert abs(r.mean_staleness - (g - 1)) < 0.5, (g, r.mean_staleness)


def test_group_batch_split_sizes_edge_cases():
    """Issue cases: sizes not summing to B, a zero-size group, bad length."""
    batch = {"x": jnp.arange(12.0)}
    with pytest.raises(ValueError):          # distinct sizes, wrong total
        group_batch_split(batch, 3, sizes=(6, 4, 4))
    with pytest.raises(ValueError):          # equal sizes, wrong total
        group_batch_split(batch, 3, sizes=(3, 3, 3))
    with pytest.raises(ValueError):          # zero-size group
        group_batch_split(batch, 3, sizes=(8, 4, 0))
    with pytest.raises(ValueError):          # len(sizes) != g
        group_batch_split(batch, 3, sizes=(8, 4))


def test_group_batch_split_wrap_fill_bias_bound():
    """The wrap-fill bias equals the closed form documented in the
    docstring and respects the (s / 4b) * range bound."""
    vals = np.array([3.0, -1.0, 7.0, 2.0, 4.0,     # group 0 (s=5)
                     1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0])  # group 1
    out = group_batch_split({"x": jnp.asarray(vals)}, 2, sizes=(5, 8))
    assert out["x"].shape == (2, 8)
    s, b = 5, 8
    r = b % s
    sl = vals[:s]
    bias = float(out["x"][0].mean()) - sl.mean()
    exact = (r * (s - r) / (s * b)) * (sl[:r].mean() - sl[r:].mean())
    np.testing.assert_allclose(bias, exact, rtol=1e-6)
    assert abs(bias) <= s / (4.0 * b) * (sl.max() - sl.min()) + 1e-9
    # the unwrapped group is exact, and wrapping repeats earliest examples
    np.testing.assert_allclose(np.asarray(out["x"][1]), vals[s:], rtol=0)
    np.testing.assert_allclose(np.asarray(out["x"][0]),
                               sl[np.arange(b) % s], rtol=0)
