"""Trainable lowering conv: custom-VJP gradients vs jax.grad through the
XLA reference conv, the backward tiling/footprint model, and the tile
autotuner (docs/lowering_conv.md)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests report as skipped; rest run
    st = None

from repro.engine import timing
from repro.kernels.lowering_conv import (autotune, bwd, choose_tiles,
                                         ops as lc_ops, vmem_bytes)
from repro.kernels.lowering_conv.ref import conv_ref, lower
from repro.models import cnn as C


def _rel_err(a, b):
    return float(jnp.abs(a - b).max() / (jnp.abs(b).max() + 1e-30))


def _layer_cases():
    """Every conv layer geometry of the three archs' smoke configs (which
    preserve the families' strides/pools — caffenet-smoke keeps the
    strided big-kernel conv1), plus the real CaffeNet conv1 kernel
    (11x11 stride 4) on a reduced image."""
    cases = []
    for arch in ("lenet", "cifarnet", "caffenet"):
        cfg = C.get_cnn_smoke_config(arch)
        for x_shape, w_shape, stride in C.conv_layer_shapes(cfg, 4):
            cases.append(pytest.param(x_shape, w_shape, stride,
                                      id=f"{arch}-{w_shape[0]}x{w_shape[1]}"
                                         f"s{stride}c{w_shape[3]}"))
    cases.append(pytest.param((2, 31, 31, 3), (11, 11, 3, 16), 4,
                              id="caffenet-conv1-11x11s4"))
    return cases


@pytest.mark.parametrize("x_shape,w_shape,stride", _layer_cases())
def test_custom_vjp_matches_xla_autodiff_per_layer(x_shape, w_shape, stride):
    """Acceptance: custom-VJP gradients match jax.grad through the XLA
    reference conv to <= 1e-5 relative error for all three archs' layer
    shapes (stride > 1 included)."""
    x = jax.random.normal(jax.random.PRNGKey(0), x_shape)
    w = jax.random.normal(jax.random.PRNGKey(1), w_shape) * 0.1

    def loss(conv):
        # non-linear readout so dy is not constant
        return lambda x, w: (jax.nn.relu(conv(x, w)) ** 2).sum()

    ref = jax.grad(loss(lambda x, w: conv_ref(x, w, stride)), (0, 1))(x, w)
    got_xla = jax.grad(loss(
        lambda x, w: lc_ops.lowering_conv_xla(x, w, stride=stride)),
        (0, 1))(x, w)
    got_pal = jax.grad(loss(
        lambda x, w: lc_ops.lowering_conv(x, w, stride=stride, bp=2, rb=3,
                                          interpret=True)), (0, 1))(x, w)
    for got, name in ((got_xla, "xla"), (got_pal, "pallas")):
        assert _rel_err(got[0], ref[0]) <= 1e-5, (name, "dx")
        assert _rel_err(got[1], ref[1]) <= 1e-5, (name, "dw")


@pytest.mark.parametrize("arch", ["lenet", "cifarnet", "caffenet"])
@pytest.mark.parametrize("impl", ["lowering", "lowering_interpret",
                                  "lowering_autodiff"])
def test_full_model_grads_match_xla(arch, impl):
    """End-to-end: the smoke CNN loss (pooled layers included) gives the
    same parameter gradients under every lowering impl as under the
    native-conv path."""
    cfg = dataclasses.replace(C.get_cnn_smoke_config(arch), conv_impl=impl)
    cfg_ref = dataclasses.replace(cfg, conv_impl="xla")
    params = C.init_params(jax.random.PRNGKey(0), cfg)
    batch = {"images": jax.random.normal(
                 jax.random.PRNGKey(2),
                 (8, cfg.image_size, cfg.image_size, cfg.in_channels)),
             "labels": jax.random.randint(jax.random.PRNGKey(3), (8,), 0,
                                          cfg.num_classes)}
    g = jax.grad(lambda p: C.loss_fn(p, batch, cfg))(params)
    g_ref = jax.grad(lambda p: C.loss_fn(p, batch, cfg_ref))(params)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(g_ref)):
        assert _rel_err(a, b) <= 1e-5


def test_needs_dgrad_false_skips_input_gradient():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 12, 12, 3))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 3, 8)) * 0.1

    def run(conv):
        return jax.grad(lambda x, w: (conv(x, w) ** 2).sum(), (0, 1))(x, w)

    full = run(lambda x, w: lc_ops.lowering_conv_xla(x, w, stride=1))
    skip = run(lambda x, w: lc_ops.lowering_conv_xla(x, w, stride=1,
                                                     needs_dgrad=False))
    assert float(jnp.abs(skip[0]).max()) == 0.0      # dx suppressed
    np.testing.assert_allclose(np.asarray(skip[1]), np.asarray(full[1]),
                               rtol=1e-6, atol=1e-6)  # dw untouched


def test_grouped_vmap_custom_vjp_matches():
    """The engine's group-vmap path batches the custom VJP (traced forms):
    gradients must survive vmap."""
    cfg = C.get_cnn_smoke_config("caffenet")     # conv_impl="lowering"
    params = C.init_params(jax.random.PRNGKey(0), cfg)
    imgs = jax.random.normal(
        jax.random.PRNGKey(2),
        (2, 4, cfg.image_size, cfg.image_size, cfg.in_channels))
    labels = jax.random.randint(jax.random.PRNGKey(3), (2, 4), 0,
                                cfg.num_classes)

    def loss(p, b):
        return C.loss_fn(p, b, cfg)

    vg = jax.vmap(jax.grad(loss), in_axes=(None, 0))(
        params, {"images": imgs, "labels": labels})
    for g in range(2):
        ref = jax.grad(loss)(params,
                             {"images": imgs[g], "labels": labels[g]})
        for a, b in zip(jax.tree.leaves(vg), jax.tree.leaves(ref)):
            assert _rel_err(a[g], b) <= 1e-5


# ---------------------------------------------------------------------------
# tiling model
# ---------------------------------------------------------------------------

def _fwd_blockspec_elems(bp, rb, h, w, cin, kh, kw, cout, stride):
    """Element counts of the refs `lowering_conv_pallas` actually binds:
    its in_specs (image block, kernel matrix), out_spec, and the lowered
    tile it builds in-kernel. Written out independently here so a change
    to either the kernel's BlockSpecs or the vmem model without the other
    fails this test."""
    wo = (w - kw) // stride + 1
    K = kh * kw * cin
    return (bp * h * w * cin) + (K * cout) + (bp * rb * wo * cout) \
        + (bp * rb * wo * K)


def _wgrad_blockspec_elems(bp, rb, h, w, cin, kh, kw, cout, stride):
    wo = (w - kw) // stride + 1
    K = kh * kw * cin
    return (bp * rb * wo * K) + (bp * rb * wo * cout) + (K * cout)


def _dgrad_blockspec_elems(bp, h, w, cin, kh, kw, cout, stride):
    ho = (h - kh) // stride + 1
    wo = (w - kw) // stride + 1
    K = kh * kw * cin
    return (bp * ho * wo * cout) + (K * cout) + (bp * ho * wo * K) \
        + (bp * h * w * cin)


if st is None:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_vmem_model_matches_blockspecs():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_choose_tiles_returns_divisors():
        pass
else:
    @settings(max_examples=30, deadline=None)
    @given(bp=st.integers(1, 8), rb=st.integers(1, 8),
           hw=st.sampled_from([12, 16, 21, 33]), cin=st.integers(1, 4),
           k=st.sampled_from([3, 5, 7]), cout=st.sampled_from([4, 16]),
           stride=st.sampled_from([1, 2, 4]))
    def test_vmem_model_matches_blockspecs(bp, rb, hw, cin, k, cout, stride):
        if hw <= k:
            return
        geom = dict(h=hw, w=hw, cin=cin, kh=k, kw=k, cout=cout,
                    stride=stride)
        assert vmem_bytes(bp=bp, rb=rb, pass_="fwd", **geom) == \
            4 * _fwd_blockspec_elems(bp, rb, stride=stride, cin=cin, kh=k,
                                     kw=k, cout=cout, h=hw, w=hw)
        assert vmem_bytes(bp=bp, rb=rb, pass_="wgrad", **geom) == \
            4 * _wgrad_blockspec_elems(bp, rb, stride=stride, cin=cin,
                                       kh=k, kw=k, cout=cout, h=hw, w=hw)
        assert vmem_bytes(bp=bp, rb=rb, pass_="dgrad", **geom) == \
            4 * _dgrad_blockspec_elems(bp, stride=stride, cin=cin, kh=k,
                                       kw=k, cout=cout, h=hw, w=hw)

    @settings(max_examples=40, deadline=None)
    @given(b=st.integers(1, 64), ho=st.integers(1, 64),
           bp=st.integers(1, 64), rb=st.integers(1, 64))
    def test_choose_tiles_returns_divisors(b, ho, bp, rb):
        """Forward and backward kernels resolve requested tiles through
        choose_tiles: results must divide the batch / output rows and
        never exceed the request (so grids are exact, no remainder
        handling in-kernel)."""
        bp_c, rb_c = choose_tiles(b, ho, bp, rb)
        assert b % bp_c == 0 and ho % rb_c == 0
        assert 1 <= bp_c <= max(1, min(bp, b))
        assert 1 <= rb_c <= max(1, min(rb, ho))


def test_vmem_model_unknown_pass_rejected():
    with pytest.raises(ValueError, match="unknown pass_"):
        vmem_bytes(bp=1, rb=1, h=8, w=8, cin=1, kh=3, kw=3, cout=4,
                   pass_="bogus")


def test_bwd_kernels_match_xla_forms():
    """Pallas wgrad/dgrad (interpret) == the XLA reference forms."""
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 13, 13, 2))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 2, 8)) * 0.1
    stride = 2
    ho = (13 - 3) // stride + 1
    dy = jax.random.normal(jax.random.PRNGKey(2), (4, ho, ho, 8))
    d_hat = lower(x, 3, 3, stride)
    dw_ref = bwd.wgrad_xla(d_hat, dy, w.shape)
    lowered = d_hat.reshape(4, ho, ho, -1)
    dw_pal = bwd.wgrad_pallas(lowered, dy, w.shape, bp=2, rb=2,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(dw_pal), np.asarray(dw_ref),
                               rtol=2e-5, atol=2e-5)
    dx_ref = bwd.dgrad_xla(dy, w, x.shape, stride)
    dx_pal = bwd.dgrad_pallas(dy, w, x.shape, stride=stride, bp=2,
                              interpret=True)
    np.testing.assert_allclose(np.asarray(dx_pal), np.asarray(dx_ref),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------

def test_tile_candidates_divisors_under_budget():
    x_shape, w_shape = (8, 16, 16, 3), (3, 3, 3, 8)
    budget = 256 << 10
    cands = autotune.tile_candidates(x_shape, w_shape, 1,
                                     budget_bytes=budget)
    assert cands, "at least one candidate"
    ho = 14
    geom = dict(h=16, w=16, cin=3, kh=3, kw=3, cout=8, stride=1)
    for bp, rb in cands:
        assert 8 % bp == 0 and ho % rb == 0
        for p in ("fwd", "wgrad", "dgrad"):
            assert vmem_bytes(bp=bp, rb=rb, pass_=p, **geom) <= budget


def test_autotune_caches_per_shape_and_stride(monkeypatch):
    autotune.clear_tile_cache()
    x_shape, w_shape = (4, 12, 12, 2), (3, 3, 2, 4)
    t1 = autotune.autotune_tiles(x_shape, w_shape, 1, iters=1, warmup=1)
    assert 4 % t1[0] == 0 and 10 % t1[1] == 0
    assert autotune.cached_tiles(x_shape, w_shape, 1) == t1
    # a second call must hit the cache — probing again would retime
    monkeypatch.setattr(
        autotune.timing, "probe",
        lambda *a, **k: (_ for _ in ()).throw(AssertionError("re-probed")))
    assert autotune.autotune_tiles(x_shape, w_shape, 1) == t1
    # the key ignores the batch dim: the engine traces the same layer at
    # batch/g (group vmap) or batch/(g*k) (per-device shards) and must
    # still hit the probed choice
    assert autotune.cached_tiles((1,) + x_shape[1:], w_shape, 1) == t1
    assert autotune.cached_tiles((64,) + x_shape[1:], w_shape, 1) == t1
    # different stride (or geometry) is a different cache line -> default
    assert autotune.cached_tiles(x_shape, w_shape, 2) == \
        autotune.DEFAULT_TILES
    monkeypatch.undo()
    # a SMALLER budget the cached choice doesn't fit forces a re-probe
    # under the new budget (a TPU budget exists to prevent VMEM OOM)
    tiny = autotune._max_vmem(1, 1, x_shape, w_shape, 1)
    t2 = autotune.autotune_tiles(x_shape, w_shape, 1, budget_bytes=tiny,
                                 iters=1, warmup=1)
    assert autotune._max_vmem(*t2, x_shape, w_shape, 1) <= tiny
    autotune.clear_tile_cache()


# ---------------------------------------------------------------------------
# timing stats (the bench emitters' min+median+IQR contract)
# ---------------------------------------------------------------------------

def test_time_stats_min_median_iqr():
    s = timing.stats_of([5.0, 1.0, 3.0, 2.0, 4.0])
    assert s.min_s == 1.0 and s.median_s == 3.0
    assert s.iqr_s == pytest.approx(2.0)
    assert s.iters == 5
    row = s.row()
    assert set(row) == {"min_us", "median_us", "iqr_us", "iters"}
    assert row["min_us"] <= row["median_us"]


def test_probe_returns_stats():
    x = jnp.ones((16, 16))
    f = jax.jit(lambda: x @ x)
    s = timing.probe(f, warmup=1, iters=3)
    assert s.iters == 3 and s.min_s > 0 and s.min_s <= s.median_s
