"""Serving subsystem tests.

The load-bearing contracts, in order:
- paged decode over page tables is BITWISE equal to the dense ring-buffer
  ``transformer.decode_step`` at the same batch width (logits AND cache
  content, full and sliding windows, ring wrap included);
- a slot's output is exactly independent of the other slots' contents and
  activity (what makes continuous batching safe);
- a request served through the continuous-batching loop produces the SAME
  argmax token sequence as running it alone through prefill + decode
  (token-level, not logit-level: batch *width* itself perturbs XLA matmul
  low bits, so cross-width comparisons pin tokens — see decode.py);
- the flash-attention decode hot path and the parallel prefill are
  numerically allclose to the XLA/scan references;
- the load generator is reproducible and rid-stable across rates;
- the page allocator recycles and the serving planner's discrete-event
  model behaves monotonically.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, MoEConfig
from repro.models import transformer as T
from repro.serving import (ContinuousServer, PageAllocator, PagedCacheSpec,
                           init_pages, paged_decode_step, poisson_trace,
                           sample_requests, static_serve_trace)


def _cfg(arch_type="dense", window=None, h=2, kv=2, hd=16, layers=2):
    moe = (MoEConfig(num_experts=4, top_k=2, d_ff_expert=32)
           if arch_type == "moe" else None)
    return ArchConfig(name=f"t-{arch_type}-kv{kv}-w{window}",
                      arch_type=arch_type, num_layers=layers,
                      d_model=h * hd, num_heads=h, num_kv_heads=kv,
                      head_dim=hd, d_ff=32, vocab_size=64, moe=moe,
                      sliding_window=window, compute_dtype="float32",
                      remat=False)


def _full_tables(spec):
    """An allocator with every slot's table fully populated."""
    alloc = PageAllocator(spec)
    for s in range(spec.num_slots):
        alloc.ensure(s, spec.seq_capacity)
    return alloc


def _gather(pages, tables, spec):
    """The dense (L, B, W, K, hd) view of the paged pool."""
    B = spec.num_slots
    return {name: np.asarray(pages[name][:, tables]).reshape(
                spec.num_layers, B, spec.seq_capacity, spec.kv_heads,
                spec.head_dim)
            for name in ("k", "v")}


# ---------------------------------------------------------------------------
# paged decode == dense ring buffer, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window,steps", [(None, 12), (8, 20)])
def test_paged_decode_bitwise_matches_dense(window, steps):
    """Same batch width, same positions: logits and cache content must be
    bit-identical to ``T.decode_step`` for ``steps`` steps — with window=8
    and 20 steps the ring wraps twice."""
    cfg = _cfg(window=window)
    B = 2
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    spec = PagedCacheSpec.for_config(cfg, num_slots=B, page_size=4,
                                     max_seq=steps if window is None else 32,
                                     window=window)
    alloc = _full_tables(spec)
    table = jnp.asarray(alloc.tables)
    pages = init_pages(spec)
    dense = T.init_cache(cfg, B, steps if window is None else 32, window)
    active = jnp.ones((B,), bool)

    dstep = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg,
                                                       window))
    pstep = jax.jit(lambda p, pg, tok, pos: paged_decode_step(
        p, pg, table, tok, pos, active, cfg, window=window))

    rng = np.random.default_rng(1)
    for t in range(steps):
        tok = jnp.asarray(rng.integers(cfg.vocab_size, size=(B, 1)),
                          jnp.int32)
        dl, dense = dstep(params, dense, tok, jnp.int32(t))
        pl, pages = pstep(params, pages, tok,
                          jnp.full((B,), t, jnp.int32))
        assert np.array_equal(np.asarray(dl), np.asarray(pl)), f"step {t}"

    view = _gather(pages, alloc.tables, spec)
    for name in ("k", "v"):
        assert np.array_equal(view[name],
                              np.asarray(dense["blocks"][name]))


def test_paged_decode_rows_are_independent():
    """Row 0's logits must not change by a single bit when row 1 flips
    between active (at a different position, different tokens) and
    inactive — the property that lets requests join/leave mid-flight."""
    cfg = _cfg()
    B = 2
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    spec = PagedCacheSpec.for_config(cfg, num_slots=B, page_size=4,
                                     max_seq=16)
    rng = np.random.default_rng(2)
    logs = []
    for neighbor_active in (True, False):
        alloc = _full_tables(spec)
        table = jnp.asarray(alloc.tables)
        pages = init_pages(spec)
        rng0 = np.random.default_rng(3)     # row 0's stream, shared
        for t in range(8):
            toks = np.zeros((B, 1), np.int32)
            toks[0, 0] = rng0.integers(cfg.vocab_size)
            toks[1, 0] = rng.integers(cfg.vocab_size)   # differs per arm
            pos = np.array([t, 2 * t + 1], np.int32)    # differs per arm
            active = jnp.asarray([True, neighbor_active])
            logits, pages = paged_decode_step(
                params, pages, table, jnp.asarray(toks),
                jnp.asarray(pos), active, cfg, window=None)
            logs.append((neighbor_active, t, np.asarray(logits[0])))
    a = [x for act, _, x in logs if act]
    b = [x for act, _, x in logs if not act]
    for t, (x, y) in enumerate(zip(a, b)):
        assert np.array_equal(x, y), f"row-0 leak at step {t}"


def test_inactive_slots_leave_scratch_page_untouched():
    cfg = _cfg()
    spec = PagedCacheSpec.for_config(cfg, num_slots=2, page_size=4,
                                     max_seq=8)
    alloc = PageAllocator(spec)          # nothing allocated: all rows at 0
    pages = init_pages(spec)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    _, pages = paged_decode_step(
        params, pages, jnp.asarray(alloc.tables),
        jnp.zeros((2, 1), jnp.int32), jnp.zeros((2,), jnp.int32),
        jnp.zeros((2,), bool), cfg, window=None)
    assert not np.asarray(pages["k"]).any()
    assert not np.asarray(pages["v"]).any()


# ---------------------------------------------------------------------------
# continuous batching == solo decoding, token-exact
# ---------------------------------------------------------------------------

def _solo_tokens(cfg, params, req, window, cache_len):
    """The request alone: prefill the exact-length prompt, then greedy
    decode — the reference token sequence. cache_len must equal the
    server's cache width (same-width softmax reduction trees are part of
    the bitwise contract)."""
    cache = T.init_cache(cfg, 1, cache_len, window)
    logits, cache = T.prefill(params, cache,
                              jnp.asarray(req.prompt[None, :]), cfg, window)
    toks = [int(jnp.argmax(logits[0, -1]))]
    pos = len(req.prompt)
    for _ in range(req.gen - 1):
        logits, cache = T.decode_step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32),
            jnp.int32(pos), cfg, window)
        toks.append(int(jnp.argmax(logits[0, -1])))
        pos += 1
    return np.array(toks, np.int32)


@pytest.mark.parametrize("arch_type,kv,window", [
    ("dense", 2, None),          # full-window MHA
    ("dense", 1, 8),             # GQA + sliding-window ring
    ("moe", 2, None),            # routed experts in the decode scan
])
def test_continuous_matches_solo(arch_type, kv, window):
    cfg = _cfg(arch_type=arch_type, kv=kv, window=window)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    trace = poisson_trace(50.0, 6, seed=3)
    reqs = sample_requests(trace, cfg, prompt_range=(4, 8),
                           gen_range=(3, 6), seed=3)
    srv = ContinuousServer(cfg, params, slots=2, page_size=4, max_seq=16,
                           window=window)
    rep = srv.run(reqs)
    assert len(rep.rids) == len(reqs)
    for r in reqs:
        want = _solo_tokens(cfg, params, r, window,
                            srv.spec.seq_capacity if window is None else 16)
        got = rep.tokens[r.rid]
        assert np.array_equal(got, want), (
            f"rid {r.rid}: continuous {got} != solo {want}")
    assert rep.total_tokens == sum(r.gen for r in reqs)
    assert (rep.queue_waits >= 0).all() and (rep.latencies > 0).all()


def test_continuous_run_is_reproducible_after_reset():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = sample_requests(poisson_trace(30.0, 5, seed=1), cfg,
                           prompt_range=(4, 8), gen_range=(3, 5), seed=1)
    srv = ContinuousServer(cfg, params, slots=2, page_size=4, max_seq=16)
    rep1 = srv.run(reqs)
    srv.reset()
    rep2 = srv.run(reqs)
    for rid in rep1.tokens:
        assert np.array_equal(rep1.tokens[rid], rep2.tokens[rid])


def test_static_baseline_accounts_every_request():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = sample_requests(poisson_trace(30.0, 5, seed=2), cfg,
                           prompt_range=(4, 8), gen_range=(3, 5), seed=2)
    rep = static_serve_trace(cfg, reqs, batch=2, params=params)
    assert len(rep.rids) == len(reqs)
    assert rep.total_tokens == sum(r.gen for r in reqs)
    for r in reqs:
        assert len(rep.tokens[r.rid]) == r.gen
    # group members share a finish time; latency is sorted by arrival wait
    assert (rep.latencies > 0).all()
    assert 0 < rep.occupancy_mean <= 1.0


# ---------------------------------------------------------------------------
# flash decode + parallel prefill hot paths
# ---------------------------------------------------------------------------

def test_pallas_decode_matches_xla():
    """q_offsets flash decode vs the masked XLA path on a primed cache."""
    cfg = _cfg(kv=1)                              # GQA through the kernel
    B = 2
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    spec = PagedCacheSpec.for_config(cfg, num_slots=B, page_size=4,
                                     max_seq=16)
    alloc = _full_tables(spec)
    table = jnp.asarray(alloc.tables)
    pages = init_pages(spec)
    rng = np.random.default_rng(4)
    pos = None
    for t in range(6):                            # prime via the XLA path
        tok = jnp.asarray(rng.integers(cfg.vocab_size, size=(B, 1)),
                          jnp.int32)
        pos = jnp.full((B,), t, jnp.int32)
        logits, pages = paged_decode_step(
            params, pages, table, tok, pos, jnp.ones((B,), bool), cfg,
            window=None, attn_impl="xla")
    tok = jnp.asarray(rng.integers(cfg.vocab_size, size=(B, 1)), jnp.int32)
    pos = jnp.full((B,), 6, jnp.int32)
    lx, _ = paged_decode_step(params, pages, table, tok, pos,
                              jnp.ones((B,), bool), cfg, window=None,
                              attn_impl="xla")
    lp, _ = paged_decode_step(params, pages, table, tok, pos,
                              jnp.ones((B,), bool), cfg, window=None,
                              attn_impl="pallas")
    np.testing.assert_allclose(np.asarray(lx), np.asarray(lp),
                               rtol=1e-4, atol=1e-4)


def test_parallel_prefill_matches_scan_tokens():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = sample_requests(poisson_trace(30.0, 4, seed=5), cfg,
                           prompt_range=(4, 8), gen_range=(3, 5), seed=5)
    tok = {}
    for mode in ("scan", "parallel"):
        srv = ContinuousServer(cfg, params, slots=2, page_size=4,
                               max_seq=16, window=None, prefill_mode=mode)
        tok[mode] = srv.run(reqs).tokens
    for rid in tok["scan"]:
        assert np.array_equal(tok["scan"][rid], tok["parallel"][rid])


# ---------------------------------------------------------------------------
# load generation
# ---------------------------------------------------------------------------

def test_poisson_trace_reproducible_and_roundtrips(tmp_path):
    a = poisson_trace(25.0, 16, seed=7)
    b = poisson_trace(25.0, 16, seed=7)
    assert np.array_equal(a.commit_time, b.commit_time)
    assert (np.diff(a.commit_time) > 0).all()
    assert np.array_equal(a.read_version, np.arange(16))  # staleness 0
    p = tmp_path / "trace.npz"
    a.save(p)
    c = type(a).load(p)
    assert np.array_equal(a.commit_time, c.commit_time)
    assert np.array_equal(a.group, c.group)
    with pytest.raises(ValueError):
        poisson_trace(0.0, 4)


def test_sample_requests_rid_stable_across_rates():
    """Request rid must be byte-identical at every offered rate — only the
    arrival times may differ (the bench replays the same work per rate)."""
    cfg = _cfg()
    r1 = sample_requests(poisson_trace(10.0, 8, seed=0), cfg, seed=9)
    r2 = sample_requests(poisson_trace(80.0, 8, seed=0), cfg, seed=9)
    for a, b in zip(r1, r2):
        assert a.rid == b.rid and a.gen == b.gen
        assert np.array_equal(a.prompt, b.prompt)
        assert a.arrival != b.arrival or a.rid == 0


# ---------------------------------------------------------------------------
# page allocator
# ---------------------------------------------------------------------------

def test_allocator_lazy_growth_recycle_and_exhaustion():
    cfg = _cfg()
    spec = PagedCacheSpec.for_config(cfg, num_slots=2, page_size=4,
                                     max_seq=16)
    al = PageAllocator(spec)
    total = spec.num_pages - 1           # scratch page 0 is never free
    assert al.pages_free == total
    al.ensure(0, 1)                      # one position -> one page
    assert al.pages_free == total - 1
    al.ensure(0, 5)                      # crosses a page boundary
    assert al.pages_free == total - 2
    al.ensure(0, 5)                      # idempotent
    assert al.pages_free == total - 2
    assert 0 not in al.tables[0, :2]     # scratch never handed out
    assert len(set(al.tables[0, :2])) == 2
    al.ensure(1, spec.seq_capacity)
    assert al.pages_free == 2
    assert al.can_fit(2 * spec.page_size)       # 2 pages still free
    assert not al.can_fit(spec.seq_capacity)    # but not 4
    al.release(0)
    assert al.pages_free == total - spec.pages_per_slot
    assert (al.tables[0] == 0).all()     # row points back at scratch
    al.release(1)
    assert al.pages_free == total
    # exhaustion guard: a drained pool must raise, not corrupt tables
    al._free.clear()
    with pytest.raises(RuntimeError):
        al.ensure(0, 1)


def test_spec_rejects_indivisible_page_size():
    cfg = _cfg()
    with pytest.raises(ValueError):
        PagedCacheSpec.for_config(cfg, num_slots=2, page_size=5, max_seq=16)


def test_request_capacity_guard():
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    srv = ContinuousServer(cfg, params, slots=2, page_size=4, max_seq=8)
    trace = poisson_trace(10.0, 1, seed=0)
    big = sample_requests(trace, cfg, prompt_range=(8, 8),
                          gen_range=(8, 8), seed=0)
    with pytest.raises(ValueError):
        srv.run(big)                     # 8 + 8 > capacity 8, full window


# ---------------------------------------------------------------------------
# serving planner / discrete-event sim
# ---------------------------------------------------------------------------

def _sim_kwargs(n=24, rate=20.0):
    rng = np.random.default_rng(0)
    return dict(arrivals=list(np.cumsum(rng.exponential(1 / rate, n))),
                prompt_lens=list(rng.integers(8, 33, n)),
                gen_lens=list(rng.integers(4, 33, n)))


def test_sim_decode_rate_is_monotone():
    from repro.cluster.serving import simulate_serving
    kw = _sim_kwargs()
    slow = simulate_serving(**kw, prefill_rates=[500.0],
                            decode_rates=[200.0], slots=8)
    fast = simulate_serving(**kw, prefill_rates=[500.0],
                            decode_rates=[200.0, 200.0], slots=8)
    assert fast.percentile(99) < slow.percentile(99)
    assert fast.makespan <= slow.makespan
    assert (slow.latencies > 0).all() and (slow.queue_waits >= 0).all()


def test_sim_validates_inputs():
    from repro.cluster.serving import simulate_serving
    kw = _sim_kwargs(n=4)
    with pytest.raises(ValueError):
        simulate_serving(**kw, prefill_rates=[], decode_rates=[1.0])
    with pytest.raises(ValueError):
        simulate_serving(**{**kw, "gen_lens": [0, 1, 1, 1]},
                         prefill_rates=[1.0], decode_rates=[1.0])
    with pytest.raises(ValueError):
        simulate_serving(**kw, prefill_rates=[1.0], decode_rates=[1.0],
                         slots=0)


def test_plan_serving_splits_pools_and_needs_two_devices():
    from repro.cluster.devices import DeviceSpec
    from repro.cluster.serving import plan_serving, tok_rate
    gpu = DeviceSpec(name="gpu", kind="gpu", peak_flops=4e12, mem_bw=2e11,
                     net_bw=1e10, throughput=400.0)
    cpu = DeviceSpec(name="cpu", kind="cpu", peak_flops=5e11, mem_bw=5e10,
                     net_bw=1e10, throughput=80.0)
    kw = _sim_kwargs()
    plan = plan_serving([gpu, gpu, cpu, cpu], slo_p99_s=1.0, **kw)
    assert plan.prefill_devices and plan.decode_devices
    assert len(plan.prefill_devices) + len(plan.decode_devices) == 4
    assert plan.goodput > 0
    assert "serving plan" in plan.describe()
    with pytest.raises(ValueError):
        plan_serving([gpu], slo_p99_s=1.0, **kw)
    assert tok_rate(gpu) == 400.0
    assert tok_rate(dataclasses.replace(gpu, throughput=None)) == 4e12 / 1e9


def test_serving_metrics_land_in_registry():
    from repro.obs.metrics import MetricRegistry
    cfg = _cfg()
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reg = MetricRegistry()
    srv = ContinuousServer(cfg, params, slots=2, page_size=4, max_seq=16,
                           registry=reg)
    reqs = sample_requests(poisson_trace(30.0, 3, seed=0), cfg,
                           prompt_range=(4, 8), gen_range=(3, 4), seed=0)
    srv.run(reqs)
    for name in ("serving.queue_wait_s", "serving.prefill_s",
                 "serving.decode_s", "serving.decode_step_s",
                 "serving.latency_s", "serving.occupancy"):
        assert len(reg.series(name).values) > 0, name
    assert reg.counter("serving.requests_completed").value == 3
    assert reg.counter("serving.tokens_generated").value == \
        sum(r.gen for r in reqs)


# ---------------------------------------------------------------------------
# bucketed gather ladder + in-kernel paged decode through the server
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window", [None, 8])
def test_bucketed_gather_matches_full_tokens(window):
    """gather_mode="bucket" narrows the decode gather to the live page
    high-water bucket; tokens must equal the full-capacity bitwise arm
    (narrowing re-tiles XLA reductions — token-level, like batch width)."""
    cfg = _cfg(window=window)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = sample_requests(poisson_trace(40.0, 6, seed=4), cfg,
                           prompt_range=(4, 8), gen_range=(3, 6), seed=4)
    toks = {}
    for gm in ("full", "bucket"):
        srv = ContinuousServer(cfg, params, slots=2, page_size=4,
                               max_seq=16, window=window, gather_mode=gm)
        srv.warmup([8])
        toks[gm] = srv.run(reqs).tokens
    for rid in toks["full"]:
        assert np.array_equal(toks["full"][rid], toks["bucket"][rid]), rid


def test_gather_bucket_uses_active_rows_only():
    """Retired slots keep stale positions; the ladder must size the
    gather from live rows alone (and never exceed capacity)."""
    cfg = _cfg()
    srv = ContinuousServer(cfg, slots=2, page_size=4, max_seq=16)
    pos = np.array([3, 900], np.int32)        # row 1 retired, stale pos
    act = np.array([True, False])
    assert srv._gather_bucket(pos, act) == 1
    assert srv._gather_bucket(pos, ~act) is None      # capacity-clamped
    assert srv._gather_bucket(pos, np.zeros(2, bool)) is None
    srv_full = ContinuousServer(cfg, slots=2, page_size=4, max_seq=16,
                                gather_mode="full")
    assert srv_full._gather_bucket(pos, act) is None


@pytest.mark.parametrize("arch_type,window", [("dense", None), ("moe", 8)])
def test_continuous_pallas_kernel_matches_xla_tokens(arch_type, window):
    """attn_impl="pallas" routes decode AND the scan-prefill inner step
    through the in-kernel page walk; the served token streams must match
    the XLA gather arm."""
    cfg = _cfg(arch_type=arch_type, kv=1, window=window)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    reqs = sample_requests(poisson_trace(40.0, 4, seed=6), cfg,
                           prompt_range=(4, 8), gen_range=(3, 5), seed=6)
    toks = {}
    for impl in ("xla", "pallas"):
        srv = ContinuousServer(cfg, params, slots=2, page_size=4,
                               max_seq=16, window=window, attn_impl=impl)
        toks[impl] = srv.run(reqs).tokens
    for rid in toks["xla"]:
        assert np.array_equal(toks["xla"][rid], toks["pallas"][rid]), rid


def test_pallas_gather_ring_fallback_warns_and_notes():
    """flash-over-a-copy cannot express a wrapped ring: constructing the
    server with attn_impl="pallas_gather" under a sliding window must
    warn AND pin a note in the metric registry — and re-pin it when a
    fresh registry is attached for a measured run."""
    from repro.obs.metrics import MetricRegistry
    cfg = _cfg(window=8)
    with pytest.warns(UserWarning, match="pallas_gather"):
        srv = ContinuousServer(cfg, slots=2, page_size=4, max_seq=16,
                               attn_impl="pallas_gather")
    assert any("falls back" in n for n in srv.registry.notes)
    fresh = MetricRegistry()
    srv.reset(registry=fresh)
    assert any("falls back" in n for n in fresh.notes)

    # full-window pallas_gather is the real flash arm: no warning, no note
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")
        srv2 = ContinuousServer(_cfg(window=None), slots=2, page_size=4,
                                max_seq=16, attn_impl="pallas_gather")
    assert srv2.registry.notes == []

    with pytest.raises(ValueError, match="attn_impl"):
        ContinuousServer(cfg, slots=2, page_size=4, max_seq=16,
                         attn_impl="nope")
    with pytest.raises(ValueError, match="gather_mode"):
        ContinuousServer(cfg, slots=2, page_size=4, max_seq=16,
                         gather_mode="nope")
