"""In-kernel paged flash-decode vs the dense-gather oracle.

The contract under test: ``kernels.paged_attention`` walking the page
table *inside* the kernel (interpret mode on CPU) computes the same
attention as gathering the pages into the dense ``(B, W, K, hd)`` ring
view and running the masked reference — full and sliding windows, ring
wrap, permuted page tables, GQA group sizes, stale retired-slot rows —
and that masked / scratch-backed pool entries cannot leak a single bit
into the value reduction.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ArchConfig, MoEConfig
from repro.kernels.paged_attention import (paged_attention,
                                           paged_attention_ref, valid_mask)
from repro.models import transformer as T
from repro.serving import (PageAllocator, PagedCacheSpec, init_pages,
                           paged_decode_step)


def _setup(B=3, K=2, G=2, hd=16, page=4, n_pages=4, seed=0,
           dtype=np.float32):
    """Random pools + a permuted table (each row owns distinct physical
    pages, in shuffled order — the allocator's recycle pattern)."""
    rng = np.random.default_rng(seed)
    P = 1 + B * n_pages                       # + reserved scratch page 0
    kp = rng.standard_normal((P, page, K, hd)).astype(dtype)
    vp = rng.standard_normal((P, page, K, hd)).astype(dtype)
    table = rng.permutation(np.arange(1, P)).reshape(B, n_pages)
    q = rng.standard_normal((B, 1, K * G, hd)).astype(dtype)
    return (jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
            jnp.asarray(table.astype(np.int32)))


@pytest.mark.parametrize("window,pos", [
    (None, [0, 5, 15]),      # fresh row, mid-page, last slot of capacity
    (16, [3, 16, 30]),       # ring: pre-wrap, first wrap, near-2x wrap
    (24, [3, 19, 30]),       # window wider than the ring (W=16 < 24)
])
def test_kernel_matches_dense_gather_ref(window, pos):
    q, kp, vp, table = _setup()
    pos = jnp.asarray(pos, jnp.int32)
    out = paged_attention(q, kp, vp, table, pos, window=window)
    ref = paged_attention_ref(q, kp, vp, table, pos, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("K,G", [(1, 4), (4, 1), (2, 4)])
def test_gqa_group_sizes(K, G):
    """Repeat-free GQA: every (kv-head, group) pairing, including MQA
    (K=1) and MHA (G=1), matches the grouped-einsum reference."""
    q, kp, vp, table = _setup(K=K, G=G, seed=K * 7 + G)
    pos = jnp.asarray([2, 9, 14], jnp.int32)
    out = paged_attention(q, kp, vp, table, pos, window=None)
    ref = paged_attention_ref(q, kp, vp, table, pos, window=None)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_bf16_pools():
    """The serving smoke config decodes bf16 pools; accumulation is fp32
    in-kernel either way."""
    q, kp, vp, table = _setup(seed=11)
    q, kp, vp = (x.astype(jnp.bfloat16) for x in (q, kp, vp))
    pos = jnp.asarray([1, 7, 13], jnp.int32)
    out = paged_attention(q, kp, vp, table, pos, window=None)
    assert out.dtype == jnp.bfloat16
    ref = paged_attention_ref(q, kp, vp, table, pos, window=None)
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(ref, dtype=np.float32),
                               rtol=3e-2, atol=3e-2)


def test_stale_retired_row_cannot_overrun_or_perturb():
    """A retired slot keeps its stale position (possibly >> capacity) and
    a scratch-backed table row. The kernel must clamp its walk (no
    out-of-bounds page index), return finite garbage for that row, and
    leave live rows' outputs untouched down to the bit."""
    q, kp, vp, table = _setup(B=2, seed=5)
    live = jnp.asarray([5, 9], jnp.int32)
    base = paged_attention(q, kp, vp, table, live, window=None)

    stale_table = table.at[1].set(0)                  # all-scratch row
    stale_pos = live.at[1].set(7 * 16 + 3)            # way past capacity
    out = paged_attention(q, kp, vp, stale_table, stale_pos, window=None)
    assert np.isfinite(np.asarray(out)).all()
    assert np.array_equal(np.asarray(out[0]), np.asarray(base[0]))


@pytest.mark.parametrize("window,pos", [
    (None, [2, 9, 14]),
    (16, [2, 9, 20]),        # row 2 wrapped: every ring slot is valid
])
def test_masked_entries_cannot_leak(window, pos):
    """Poison every pool entry the mask excludes (dead-tail slots beyond
    each row's position, plus the scratch page) with huge finite garbage:
    the output must not move by a single bit."""
    q, kp, vp, table = _setup(seed=8)
    page = kp.shape[1]
    W = table.shape[1] * page
    pos = jnp.asarray(pos, jnp.int32)
    clean = paged_attention(q, kp, vp, table, pos, window=window)

    ok = np.asarray(valid_mask(pos, W, window))       # (B, W)
    kp_p, vp_p = np.asarray(kp).copy(), np.asarray(vp).copy()
    kp_p[0], vp_p[0] = 1e9, 1e9                       # scratch page
    tbl = np.asarray(table)
    for b in range(tbl.shape[0]):
        for s in np.nonzero(~ok[b])[0]:
            kp_p[tbl[b, s // page], s % page] = 1e9
            vp_p[tbl[b, s // page], s % page] = 1e9
    assert (~ok).any() or window is not None          # poisoned something
    out = paged_attention(q, jnp.asarray(kp_p), jnp.asarray(vp_p),
                          table, pos, window=window)
    assert np.array_equal(np.asarray(out), np.asarray(clean))


def test_shape_validation():
    q, kp, vp, table = _setup()
    pos = jnp.zeros((3,), jnp.int32)
    with pytest.raises(ValueError, match="one query token"):
        paged_attention(jnp.concatenate([q, q], axis=1), kp, vp, table, pos)
    with pytest.raises(ValueError, match="multiple of"):
        paged_attention(q[:, :, :3], kp, vp, table, pos)
    with pytest.raises(ValueError, match="exceeds"):
        paged_attention(q, kp, vp, table, pos, window=8)   # ring W=16 > 8


# ---------------------------------------------------------------------------
# the kernel inside the serving decode step
# ---------------------------------------------------------------------------

def _cfg(arch_type="dense", window=None):
    moe = (MoEConfig(num_experts=4, top_k=2, d_ff_expert=32)
           if arch_type == "moe" else None)
    return ArchConfig(name=f"pa-{arch_type}-w{window}", arch_type=arch_type,
                      num_layers=2, d_model=32, num_heads=2, num_kv_heads=1,
                      head_dim=16, d_ff=32, vocab_size=64, moe=moe,
                      sliding_window=window, compute_dtype="float32",
                      remat=False)


@pytest.mark.parametrize("arch_type,window", [
    ("dense", None), ("dense", 8), ("moe", None),
])
def test_decode_step_pallas_matches_xla(arch_type, window):
    """Full decode stacks (dense and MoE, GQA heads, ring included)
    through ``attn_impl="pallas"`` vs the masked XLA gather — logits
    allclose at every step, cache writes identical."""
    cfg = _cfg(arch_type, window)
    B = 2
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    spec = PagedCacheSpec.for_config(cfg, num_slots=B, page_size=4,
                                     max_seq=16, window=window)
    alloc = PageAllocator(spec)
    for s in range(B):
        alloc.ensure(s, spec.seq_capacity)
    table = jnp.asarray(alloc.tables)
    pages = {"xla": init_pages(spec), "pallas": init_pages(spec)}
    active = jnp.ones((B,), bool)
    rng = np.random.default_rng(6)
    steps = 12 if window is None else 14              # ring wraps at 8
    for t in range(steps):
        tok = jnp.asarray(rng.integers(cfg.vocab_size, size=(B, 1)),
                          jnp.int32)
        pos = jnp.full((B,), t, jnp.int32)
        logits = {}
        for impl in ("xla", "pallas"):
            logits[impl], pages[impl] = paged_decode_step(
                params, pages[impl], table, tok, pos, active, cfg,
                window=window, attn_impl=impl)
        np.testing.assert_allclose(np.asarray(logits["xla"]),
                                   np.asarray(logits["pallas"]),
                                   rtol=1e-4, atol=1e-4, err_msg=f"step {t}")
    # layer-0 writes are bitwise (projected from the shared embedding);
    # deeper layers' KV sit downstream of layer-0's attention output, so
    # cross-impl they are allclose, not bit-equal
    for name in ("k", "v"):
        a = np.asarray(pages["xla"][name])
        b = np.asarray(pages["pallas"][name])
        assert np.array_equal(a[0], b[0])
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
