"""Per-architecture smoke tests (spec deliverable f): REDUCED variant of each
assigned family — forward + one SGD train step on CPU, asserting output
shapes and no NaNs; decode step for decoder archs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config, list_archs
from repro.models import transformer as T
from repro.optim.sgd import init_momentum, sgd_update

B, S = 2, 32


def _batch(cfg):
    key = jax.random.PRNGKey(7)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.arch_type == "encdec":
        batch["enc_emb"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model)) * 0.1
    if cfg.arch_type == "vlm":
        batch["img_emb"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    logits, aux, _ = T.forward(params, _batch(cfg), cfg)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg)

    @jax.jit
    def step(params, mom, batch):
        loss, grads = jax.value_and_grad(T.lm_loss)(params, batch, cfg)
        params, mom = sgd_update(params, grads, mom, lr=0.01, momentum=0.9)
        return params, mom, loss

    mom = init_momentum(params)
    p1, m1, loss1 = step(params, mom, batch)
    p2, m2, loss2 = step(p1, m1, batch)
    assert bool(jnp.isfinite(loss1)) and bool(jnp.isfinite(loss2))
    assert float(loss2) < float(loss1)  # two steps on same batch must descend
    finite = jax.tree.all(jax.tree.map(
        lambda a: bool(jnp.isfinite(a).all()), p2))
    assert finite


@pytest.mark.parametrize("arch", list_archs())
def test_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    cache = T.init_cache(cfg, B, 64)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = T.decode_step(params, cache, tok, jnp.int32(0), cfg)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    same = jax.tree.map(lambda a, b: a.shape == b.shape, cache, new_cache)
    assert jax.tree.all(same)
