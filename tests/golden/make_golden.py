"""Regenerate the golden event-trace fixtures.

    PYTHONPATH=src python tests/golden/make_golden.py

The fixtures pin the event-loop semantics of ``queue_sim.simulate`` and
``cluster.sim.simulate_hetero`` bit-exactly (commit order, read versions,
float64 commit times): ``tests/test_exec_replay.py`` re-runs the
simulators with the same arguments and requires ``array_equal`` against
these files, so any drift in RNG consumption order or event handling
fails loudly. Only regenerate after an INTENTIONAL semantic change, and
say so in the commit message.
"""
import pathlib

from repro.cluster.sim import simulate_hetero
from repro.core.queue_sim import simulate

HERE = pathlib.Path(__file__).resolve().parent

QUEUE_ARGS = dict(g=4, t_conv=1.0, t_fc=0.1, iters=64, exponential=True,
                  seed=7)
HETERO_ARGS = dict(t_conv=[0.5, 1.0, 2.0], t_fc=0.1, iters=64,
                   exponential=True, seed=3, slowdown=[1.0, 1.0, 1.5])


def main():
    _, tr = simulate(**QUEUE_ARGS, return_trace=True)
    tr.save(HERE / "queue_sim_g4.npz")
    print(f"queue_sim_g4.npz: {len(tr)} commits, "
          f"mean staleness {tr.staleness.mean():.3f}")
    _, tr = simulate_hetero(**HETERO_ARGS, return_trace=True)
    tr.save(HERE / "hetero_g3.npz")
    print(f"hetero_g3.npz: {len(tr)} commits, "
          f"mean staleness {tr.staleness.mean():.3f}")


if __name__ == "__main__":
    main()
