"""Closed-form fused grouped update vs the sequential scan reference:
coefficient algebra, leaf-kernel parity (XLA ref + Pallas interpret), full
train-step equivalence, and the g=1 reduction to plain sgd_update."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.async_sgd import (make_grouped_train_step,
                                  scan_grouped_update)
from repro.core.workload import mlp_classify
from repro.kernels.fused_update.fused_update import fused_update_pallas
from repro.kernels.fused_update.ops import fused_group_update, fused_update
from repro.kernels.fused_update.ref import fused_update_ref
from repro.optim.closed_form import grouped_coeffs, head_coeffs
from repro.optim.sgd import sgd_update


def _tree(key, extra_leaves=True):
    ks = jax.random.split(key, 4)
    t = {"w": jax.random.normal(ks[0], (37, 53)),
         "fc": jax.random.normal(ks[1], (13,))}
    if extra_leaves:
        t["b"] = jax.random.normal(ks[2], (5, 3, 7))
        t["s"] = jnp.float32(0.3)          # scalar leaf
    return t


def _grads(key, params, g):
    return jax.tree.map(
        lambda p: jax.random.normal(key, (g,) + p.shape), params)


def _assert_trees_close(a, b, rtol=1e-5, atol=1e-6):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# update-application equivalence (no loss fn — direct on stacked gradients)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g", [1, 2, 4, 8])
@pytest.mark.parametrize("mu", [0.0, 0.9])
@pytest.mark.parametrize("wd", [0.0, 1e-4])
def test_fused_matches_scan(g, mu, wd):
    params = _tree(jax.random.PRNGKey(0))
    mom = jax.tree.map(lambda p: 0.1 * jnp.ones_like(p), params)
    grads = _grads(jax.random.PRNGKey(7), params, g)
    mask = jax.tree.map(lambda _: False, params)
    mask["fc"] = True                       # merged-FC head leaf
    ref_p, ref_v = scan_grouped_update(params, grads, mom, lr=0.05,
                                       momentum=mu, weight_decay=wd,
                                       head_mask=mask)
    c = grouped_coeffs(g, lr=0.05, momentum=mu, weight_decay=wd)
    hc = head_coeffs(g, lr=0.05, momentum=mu, weight_decay=wd)
    for impl in ("xla", "pallas"):
        p, v = fused_group_update(params, grads, mom, coeffs=c,
                                  head_coeffs=hc, head_mask=mask, impl=impl,
                                  interpret=True)
        _assert_trees_close(ref_p, p)
        _assert_trees_close(ref_v, v)


@pytest.mark.parametrize("strategy", ["fused", "scan"])
def test_g1_reduces_to_sgd_update(strategy):
    """Both strategies at g=1 must be plain synchronous sgd_update."""
    params = _tree(jax.random.PRNGKey(1))
    mom = jax.tree.map(lambda p: 0.2 * jnp.ones_like(p), params)
    grads = _grads(jax.random.PRNGKey(8), params, 1)
    g0 = jax.tree.map(lambda x: x[0], grads)
    ref_p, ref_v = sgd_update(params, g0, mom, lr=0.03, momentum=0.9,
                              weight_decay=1e-4)
    if strategy == "scan":
        p, v = scan_grouped_update(params, grads, mom, lr=0.03, momentum=0.9,
                                   weight_decay=1e-4)
    else:
        p, v = fused_group_update(
            params, grads, mom,
            coeffs=grouped_coeffs(1, lr=0.03, momentum=0.9, weight_decay=1e-4),
            head_coeffs=head_coeffs(1, lr=0.03, momentum=0.9,
                                    weight_decay=1e-4))
    _assert_trees_close(ref_p, p, rtol=1e-6, atol=1e-7)
    _assert_trees_close(ref_v, v, rtol=1e-6, atol=1e-7)


def test_head_mask_without_head_coeffs_raises():
    params = {"fc": jnp.ones((3,))}
    mom = jax.tree.map(jnp.zeros_like, params)
    grads = _grads(jax.random.PRNGKey(0), params, 2)
    with pytest.raises(ValueError, match="head_coeffs"):
        fused_group_update(params, grads, mom,
                           coeffs=grouped_coeffs(2, lr=0.1),
                           head_mask={"fc": True})


def test_momentum_dtype_roundtrip():
    """Reduced-dtype momentum buffers survive the fused path (fp32 accumulate,
    single cast back)."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(2), (33, 17))}
    mom = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.bfloat16), params)
    grads = _grads(jax.random.PRNGKey(3), params, 4)
    c = grouped_coeffs(4, lr=0.05, momentum=0.9)
    p, v = fused_group_update(params, grads, mom, coeffs=c)
    assert v["w"].dtype == jnp.bfloat16
    assert p["w"].dtype == params["w"].dtype
    ref_p, ref_v = scan_grouped_update(params, grads, mom, lr=0.05,
                                       momentum=0.9)
    # scan quantizes V to bf16 after EVERY sub-step and that error feeds
    # back into W; fused quantizes once — agreement only at bf16 resolution
    _assert_trees_close(ref_p, p, rtol=2e-2, atol=2e-2)
    _assert_trees_close(ref_v, v, rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# Pallas leaf kernel vs XLA oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1,), (7,), (128,), (300,), (37, 53),
                                   (2, 3, 5, 7), ()])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pallas_leaf_matches_ref(shape, dtype):
    g = 4
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    w = jax.random.normal(ks[0], shape).astype(dtype)
    v = jax.random.normal(ks[1], shape).astype(dtype)
    gs = jax.random.normal(ks[2], (g,) + shape).astype(dtype)
    c = grouped_coeffs(g, lr=0.05, momentum=0.9, weight_decay=1e-4)
    rw, rv = fused_update_ref(w, v, gs, c)
    pw, pv = fused_update_pallas(w, v, gs, c, interpret=True)
    tol = 2e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(pw, np.float32),
                               np.asarray(rw, np.float32), rtol=tol, atol=tol)
    np.testing.assert_allclose(np.asarray(pv, np.float32),
                               np.asarray(rv, np.float32), rtol=tol, atol=tol)


def test_public_leaf_entry_point():
    """ops.fused_update (the jit'd per-leaf API) agrees across impls."""
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    w, v = jax.random.normal(ks[0], (40, 9)), jax.random.normal(ks[1], (40, 9))
    gs = jax.random.normal(ks[2], (4, 40, 9))
    c = grouped_coeffs(4, lr=0.05, momentum=0.9, weight_decay=1e-4)
    x = fused_update(w, v, gs, coeffs=c, impl="xla")
    p = fused_update(w, v, gs, coeffs=c, impl="pallas", interpret=True)
    _assert_trees_close(x, p, rtol=2e-6, atol=2e-6)


def test_pallas_block_sizes():
    """Every block_rows choice computes the same function."""
    g, shape = 2, (70, 90)
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    w, v = jax.random.normal(ks[0], shape), jax.random.normal(ks[1], shape)
    gs = jax.random.normal(ks[2], (g,) + shape)
    c = grouped_coeffs(g, lr=0.1, momentum=0.5)
    ref = fused_update_pallas(w, v, gs, c, block_rows=256, interpret=True)
    for br in (8, 16, 64):
        out = fused_update_pallas(w, v, gs, c, block_rows=br, interpret=True)
        _assert_trees_close(ref, out, rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# full train step: fused strategy vs scan strategy
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g", [1, 2, 4])
def test_train_step_strategies_agree(g):
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    batches = wl.sample_batches(jax.random.PRNGKey(1), 3 * g, wl.batch_size)
    steps = {s: jax.jit(make_grouped_train_step(
        wl.loss_fn, num_groups=g, lr=0.05, momentum=0.9, weight_decay=1e-4,
        strategy=s)) for s in ("fused", "scan")}
    state = {s: (params, jax.tree.map(jnp.zeros_like, params))
             for s in steps}
    for t in range(3):
        batch = jax.tree.map(
            lambda x: x[t * g:(t + 1) * g], batches)  # (g, B, ...) per round
        losses = {}
        for s, fn in steps.items():
            p, m = state[s]
            p, m, losses[s] = fn(p, m, batch)
            state[s] = (p, m)
        np.testing.assert_allclose(float(losses["fused"]),
                                   float(losses["scan"]), rtol=1e-5)
    _assert_trees_close(state["fused"][0], state["scan"][0])
    _assert_trees_close(state["fused"][1], state["scan"][1])


def test_coeffs_no_momentum_no_decay_is_summed_lr():
    """mu=0, lambda=0: every group contributes exactly -eta (the scan just
    subtracts eta*g_i g times); momentum vector is -eta only for the last."""
    c = grouped_coeffs(4, lr=0.1)
    np.testing.assert_allclose(c.a, [-0.1] * 4, rtol=1e-12)
    np.testing.assert_allclose(c.b, [0.0, 0.0, 0.0, -0.1], atol=1e-12)
    assert c.cww == 1.0 and c.cvv == 0.0


def test_coeffs_momentum_powers():
    """lambda=0: a_i = -eta*(1-mu^{g-i})/(1-mu), b_i = -eta*mu^{g-1-i},
    V scaled by mu^g — the powers-of-mu form from the closed-form writeup."""
    g, eta, mu = 8, 0.05, 0.9
    c = grouped_coeffs(g, lr=eta, momentum=mu)
    for i in range(g):
        np.testing.assert_allclose(c.a[i], -eta * (1 - mu ** (g - i)) / (1 - mu),
                                   rtol=1e-12)
        np.testing.assert_allclose(c.b[i], -eta * mu ** (g - 1 - i), rtol=1e-12)
    np.testing.assert_allclose(c.cvv, mu ** g, rtol=1e-12)
    assert c.cww == 1.0 and c.cvw == 0.0


try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests report as skipped; rest run
    st = None

if st is None:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_fused_matches_scan_property():
        pass
else:
    @settings(max_examples=15, deadline=None)
    @given(g=st.sampled_from([1, 2, 3, 5, 8]),
           mu=st.sampled_from([0.0, 0.3, 0.9]),
           wd=st.sampled_from([0.0, 1e-4, 1e-2]),
           lr=st.sampled_from([0.01, 0.1]),
           seed=st.integers(0, 2 ** 30))
    def test_fused_matches_scan_property(g, mu, wd, lr, seed):
        params = {"w": jax.random.normal(jax.random.PRNGKey(seed), (19, 23))}
        mom = jax.tree.map(lambda p: 0.05 * jnp.ones_like(p), params)
        grads = _grads(jax.random.PRNGKey(seed + 1), params, g)
        ref = scan_grouped_update(params, grads, mom, lr=lr, momentum=mu,
                                  weight_decay=wd)
        out = fused_group_update(
            params, grads, mom,
            coeffs=grouped_coeffs(g, lr=lr, momentum=mu, weight_decay=wd))
        _assert_trees_close(ref[0], out[0])
        _assert_trees_close(ref[1], out[1])
