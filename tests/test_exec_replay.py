"""Conformance + golden-trace tests for the trace-driven execution engine.

Pins three contracts:
- golden fixtures: the simulators' event-loop semantics are bit-exact
  against ``tests/golden/*.npz`` (commit order, read versions, times);
- conformance: replaying deterministic round-robin traces reproduces the
  two existing reference implementations (``delayed_sgd_run`` and the
  grouped ``strategy="scan"`` step) to fp32 tolerance, and the three
  replay implementations agree with each other on stochastic traces;
- Theorem 1, executed: replaying exponential-service traces with explicit
  mu = 0 recovers implicit momentum 1 - 1/g (the paper's Fig. 6).
"""
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster.sim import simulate_hetero
from repro.core import queue_sim
from repro.core.async_sgd import delayed_sgd_run, make_grouped_train_step
from repro.core.implicit_momentum import measure_effective_momentum
from repro.core.stat_model import measured_se_from_replay
from repro.core.workload import mlp_classify, quadratic
from repro.exec import (EventTrace, replay_trace, replay_trace_fused,
                        replay_trace_python, replay_trace_scan,
                        replayed_momentum_experiment)
from repro.optim.sgd import init_momentum

GOLDEN = pathlib.Path(__file__).resolve().parent / "golden"


def _golden_args():
    """Simulator arguments the fixtures were generated with (single source
    of truth: tests/golden/make_golden.py, loaded by path — the tests tree
    is not a package)."""
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "make_golden", GOLDEN / "make_golden.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod.QUEUE_ARGS, mod.HETERO_ARGS


def _leaves_close(a, b, rtol=1e-5, atol=1e-6):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# EventTrace record
# ---------------------------------------------------------------------------

def test_trace_validation_and_staleness():
    tr = EventTrace.round_robin(4, 12, mode="grouped")
    assert len(tr) == 12 and tr.num_groups == 4
    assert tr.staleness.tolist() == [0, 1, 2, 3] * 3
    assert tr.max_staleness == 3
    assert tr.equal_read_runs() == 4
    td = EventTrace.round_robin(4, 12, mode="delayed")
    assert td.staleness.tolist() == [0, 1, 2] + [3] * 9
    assert td.equal_read_runs() is None
    with pytest.raises(ValueError):       # read_version > t
        EventTrace(num_groups=2, group=[0, 1], read_version=[0, 2],
                   commit_time=[1.0, 2.0])
    with pytest.raises(ValueError):       # group id out of range
        EventTrace(num_groups=2, group=[0, 2], read_version=[0, 0],
                   commit_time=[1.0, 2.0])


def test_trace_save_load_roundtrip(tmp_path):
    _, tr = queue_sim.simulate(g=3, t_conv=1.0, t_fc=0.1, iters=20,
                               seed=5, return_trace=True)
    p = tmp_path / "t.npz"
    tr.save(p)
    back = EventTrace.load(p)
    assert back.num_groups == tr.num_groups
    for f in ("group", "read_version", "commit_time"):
        assert np.array_equal(getattr(back, f), getattr(tr, f))


def test_truncate_keeps_validity():
    _, tr = queue_sim.simulate(g=4, t_conv=1.0, t_fc=0.1, iters=30,
                               seed=1, return_trace=True)
    short = tr.truncate(7)
    assert len(short) == 7
    assert np.array_equal(short.read_version, tr.read_version[:7])


# ---------------------------------------------------------------------------
# Golden fixtures: event-loop semantics pinned bit-exactly
# ---------------------------------------------------------------------------

def test_golden_queue_sim_trace():
    QUEUE_ARGS, _ = _golden_args()
    golden = EventTrace.load(GOLDEN / "queue_sim_g4.npz")
    _, fresh = queue_sim.simulate(**QUEUE_ARGS, return_trace=True)
    assert fresh.num_groups == golden.num_groups
    assert np.array_equal(fresh.group, golden.group)
    assert np.array_equal(fresh.read_version, golden.read_version)
    assert np.array_equal(fresh.commit_time, golden.commit_time)  # bit-exact


def test_golden_hetero_trace():
    _, HETERO_ARGS = _golden_args()
    golden = EventTrace.load(GOLDEN / "hetero_g3.npz")
    _, fresh = simulate_hetero(**HETERO_ARGS, return_trace=True)
    assert fresh.num_groups == golden.num_groups
    assert np.array_equal(fresh.group, golden.group)
    assert np.array_equal(fresh.read_version, golden.read_version)
    assert np.array_equal(fresh.commit_time, golden.commit_time)  # bit-exact


def test_return_trace_does_not_change_sim_result():
    kw = dict(g=3, t_conv=1.0, t_fc=0.2, iters=50, seed=11)
    plain = queue_sim.simulate(**kw)
    recorded, tr = queue_sim.simulate(**kw, return_trace=True)
    assert plain.time_per_iteration == recorded.time_per_iteration
    assert plain.mean_staleness == recorded.mean_staleness
    assert np.array_equal(plain.staleness_hist, recorded.staleness_hist)
    # the trace's own staleness reproduces the sim's bookkeeping
    st = tr.staleness[len(tr) // 10:]
    assert float(st.mean()) == plain.mean_staleness


# ---------------------------------------------------------------------------
# Conformance with the reference implementations
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g", [2, 4])
def test_delayed_round_robin_matches_delayed_sgd(g):
    """Replay of the deterministic delayed-mode trace == delayed_sgd_run
    at S = g-1 (params and per-step losses, fp32 tolerance)."""
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    batches = wl.sample_batches(jax.random.PRNGKey(1), 3 * g, wl.batch_size)
    tr = EventTrace.round_robin(g, 3 * g, mode="delayed")
    ref_p, ref_l, _ = delayed_sgd_run(wl.loss_fn, params, batches,
                                      staleness=g - 1, lr=0.05, momentum=0.6)
    for impl in ("python", "scan"):
        got_p, got_l, _ = replay_trace(wl.loss_fn, params, batches, tr,
                                       lr=0.05, momentum=0.6, impl=impl)
        _leaves_close(got_p, ref_p)
        np.testing.assert_allclose(np.asarray(got_l), np.asarray(ref_l),
                                   rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("g", [1, 2, 4])
def test_grouped_round_robin_matches_scan_strategy(g):
    """Replay of the grouped-mode trace == the ``strategy="scan"`` grouped
    reference applied round by round (momentum + weight decay on)."""
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    rounds = 3
    batches = wl.sample_batches(jax.random.PRNGKey(1), rounds * g,
                                wl.batch_size)
    tr = EventTrace.round_robin(g, rounds * g, mode="grouped")
    step = make_grouped_train_step(wl.loss_fn, num_groups=g, lr=0.05,
                                   momentum=0.6, weight_decay=0.01,
                                   strategy="scan")
    p, m = params, init_momentum(params)
    for r in range(rounds):
        gb = jax.tree.map(lambda x: x[r * g:(r + 1) * g], batches)
        p, m, _ = step(p, m, gb)
    for impl in ("python", "scan", "fused"):
        got_p, _, _ = replay_trace(wl.loss_fn, params, batches, tr, lr=0.05,
                                   momentum=0.6, weight_decay=0.01,
                                   impl=impl)
        _leaves_close(got_p, p, rtol=2e-5, atol=2e-6)


def test_scan_replay_equals_python_on_stochastic_trace():
    """Jittable replay == Python reference along a simulated trace."""
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(2))
    _, tr = queue_sim.simulate(g=3, t_conv=1.0, t_fc=0.1, iters=24,
                               seed=13, return_trace=True)
    batches = wl.sample_batches(jax.random.PRNGKey(3), len(tr),
                                wl.batch_size)
    ref_p, ref_l, ref_t = replay_trace_python(
        wl.loss_fn, params, batches, tr, lr=0.05, momentum=0.3,
        weight_decay=0.01, record_params=True)
    got_p, got_l, got_t = replay_trace_scan(
        wl.loss_fn, params, batches, tr, lr=0.05, momentum=0.3,
        weight_decay=0.01, record_params=True)
    _leaves_close(got_p, ref_p)
    _leaves_close(got_t, ref_t)
    np.testing.assert_allclose(np.asarray(got_l), ref_l, rtol=1e-5,
                               atol=1e-6)


def test_fused_requires_run_structure():
    _, tr = queue_sim.simulate(g=3, t_conv=1.0, t_fc=0.1, iters=20,
                               seed=17, return_trace=True)
    assert tr.equal_read_runs() is None
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(0))
    batches = wl.sample_batches(jax.random.PRNGKey(1), len(tr),
                                wl.batch_size)
    with pytest.raises(ValueError):
        replay_trace_fused(wl.loss_fn, params, batches, tr, lr=0.05)
    # fused keeps no history: a depth cap must error, not silently no-op
    grouped = EventTrace.round_robin(4, 20, mode="grouped")
    with pytest.raises(ValueError):
        replay_trace(wl.loss_fn, params, batches, grouped, lr=0.05,
                     impl="fused", depth=2)


def test_depth_buckets_staleness_to_ring():
    """depth=1 keeps only the live version: every commit reads fresh
    params — identical to replaying the zero-staleness trace."""
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(4))
    _, tr = queue_sim.simulate(g=4, t_conv=1.0, t_fc=0.1, iters=16,
                               seed=23, return_trace=True)
    assert tr.max_staleness >= 1
    batches = wl.sample_batches(jax.random.PRNGKey(5), len(tr),
                                wl.batch_size)
    fresh = EventTrace(num_groups=tr.num_groups, group=tr.group,
                       read_version=np.arange(len(tr)),
                       commit_time=tr.commit_time)
    ref_p, _, _ = replay_trace_scan(wl.loss_fn, params, batches, fresh,
                                    lr=0.05, momentum=0.3)
    got_p, _, _ = replay_trace_scan(wl.loss_fn, params, batches, tr,
                                    lr=0.05, momentum=0.3, depth=1)
    _leaves_close(got_p, ref_p)


# ---------------------------------------------------------------------------
# Theorem 1, executed (paper Fig. 6) — the acceptance experiment
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("g,runs", [(2, 2000), (4, 600), (8, 600)])
def test_replayed_momentum_recovers_one_minus_inv_g(g, runs):
    """Replaying exponential-service traces (explicit mu = 0) through
    ``measure_effective_momentum`` recovers 1 - 1/g within 10%."""
    traj = replayed_momentum_experiment(g, eta=0.2, steps=300, runs=runs,
                                        seed=g)
    w = traj[3:]
    keep = np.nonzero(np.abs(w) >= 1e-3)[0]   # drop the MC-noise tail
    if keep.size:
        w = w[:keep[-1] + 1]
    mu = measure_effective_momentum(w[:, None], w[:, None], lr=0.2,
                                    fit_lr=True)
    th = 1.0 - 1.0 / g
    assert abs(mu - th) / th < 0.10, (g, mu, th)


# ---------------------------------------------------------------------------
# Measured SE from replayed executions
# ---------------------------------------------------------------------------

def test_measured_se_from_replay_semantics():
    curves = {1: np.linspace(1.0, 0.0, 101),          # hits 0.5 at ~50
              4: np.linspace(1.0, 0.5, 101),          # hits 0.5 at 100
              8: np.full(101, 1.0)}                   # never converges
    out = measured_se_from_replay(curves, 0.5, smooth=1)
    assert out[1]["P_SE"] == pytest.approx(1.0)
    assert out[4]["se_iters"] > out[1]["se_iters"]
    assert out[4]["P_SE"] == pytest.approx(out[4]["se_iters"]
                                           / out[1]["se_iters"])
    assert out[8]["se_iters"] is None and out[8]["P_SE"] is None
    with pytest.raises(ValueError):       # no sync baseline to normalize to
        measured_se_from_replay({2: curves[4], 4: curves[4]}, 0.5)


def test_planner_accepts_measured_se_penalties():
    from repro.cluster import DeviceSpec, best_allocation
    devices = tuple(DeviceSpec(f"d{i}", "cpu", 1e12, 1e11, 1e9,
                               throughput=100.0) for i in range(4))
    kw = dict(global_batch=16, t_fc=1e-4)
    analytic = best_allocation(devices, **kw)
    # measured penalties that make large g terrible force the plan sync
    measured = {g: (1.0 if g == 1 else 100.0) for g in range(1, 5)}
    calibrated = best_allocation(devices, se_penalties=measured, **kw)
    assert calibrated.g == 1
    assert calibrated.se_penalty == 1.0
    assert analytic.time_score > 0


# ---------------------------------------------------------------------------
# Convergence-scale replays (non-blocking slow CI job)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_replay_se_convergence_hundreds_of_commits():
    """Replaying a stale trace must not converge faster than the sync
    trace on a smooth problem; measured_se_from_replay sees the ordering."""
    wl = quadratic(dim=8, cond=3.0, noise=0.0)
    params = wl.init(jax.random.PRNGKey(0))
    steps = 400
    batches = wl.sample_batches(jax.random.PRNGKey(1), steps, 1)
    curves = {}
    for g in (1, 8):
        tr = EventTrace.round_robin(g, steps, mode="delayed")
        _, losses, _ = replay_trace_scan(wl.loss_fn, params, batches, tr,
                                         lr=0.3, momentum=0.0)
        curves[g] = np.asarray(losses)
    target = float(np.convolve(curves[1], np.ones(5) / 5,
                               mode="valid")[:240].min())
    out = measured_se_from_replay(curves, target)
    assert out[1]["se_iters"] is not None
    se8 = out[8]["se_iters"]
    assert se8 is None or se8 >= out[1]["se_iters"]


@pytest.mark.slow
def test_train_driver_replay_smoke(tmp_path):
    """launch/train.py --replay-trace end-to-end on a recorded trace."""
    from repro.launch import train as train_mod
    _, tr = queue_sim.simulate(g=4, t_conv=1.0, t_fc=0.05, iters=16,
                               seed=2, return_trace=True)
    p = tmp_path / "trace.npz"
    tr.save(p)
    losses = train_mod.main([
        "--arch", "qwen2-7b", "--smoke", "--steps", "12", "--batch", "2",
        "--seq", "16", "--lr", "0.05", "--momentum", "0.3",
        "--replay-trace", str(p)])
    assert len(losses) == 12
    assert np.isfinite(losses).all()
