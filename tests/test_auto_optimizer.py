"""Algorithm 1 (automatic optimizer) + GP-EI baseline behaviour."""
import numpy as np
import pytest

from repro.core import hardware_model as hm
from repro.core.auto_optimizer import algorithm1, cold_start, grid_search
from repro.core.bayesian import gp_ei_minimize
from repro.core.workload import init_state, make_runner, mlp_classify


@pytest.fixture(scope="module")
def runner_state():
    wl = mlp_classify()
    return make_runner(wl, seed=0), init_state(wl, seed=0)


def test_cold_start_finds_converging_eta(runner_state):
    runner, state = runner_state
    mu, eta, loss = cold_start(runner, state, probe_steps=40)
    assert mu == 0.9
    assert eta in (0.1, 0.01, 0.001, 0.0001, 0.00001)
    assert np.isfinite(loss)


def test_grid_search_picks_finite_best(runner_state):
    runner, state = runner_state
    mu, eta, loss = grid_search(runner, state, g=4, etas=(0.1, 0.01),
                                mus=(0.0, 0.3, 0.6, 0.9), probe_steps=40)
    assert np.isfinite(loss)
    assert 0.0 <= mu <= 0.9


def test_algorithm1_end_to_end(runner_state):
    runner, state = runner_state
    res = algorithm1(runner, state, n_devices=16, epochs=2, epoch_steps=120,
                     probe_steps=30, g0=8)
    assert res.g >= 1 and res.g <= 8
    assert res.decisions[0].phase == "cold"
    # training must actually make progress
    assert res.losses[-20:].mean() < res.losses[:20].mean()


def test_algorithm1_he_short_circuit():
    """With FC dominating, the HE model should start the search at small g."""
    ph = hm.PhaseTimes(t_conv_compute_1=1.0, t_fc=0.5, conv_grad_bytes=0.0)
    assert hm.smallest_saturating_g(16, ph) <= 4


def test_gp_ei_finds_good_point():
    # simple bowl over the grid: best at eta=0.01, mu=0.6, g=4
    def obj(eta, mu, g):
        return ((np.log10(eta) + 2) ** 2 + (mu - 0.6) ** 2
                + (np.log2(g) - 2) ** 2)
    res = gp_ei_minimize(obj, etas=(0.1, 0.01, 0.001), mus=(0.0, 0.3, 0.6, 0.9),
                         gs=(1, 2, 4, 8), budget=18, seed=0)
    assert res.best_x == (0.01, 0.6, 4)


def test_gp_ei_handles_divergence():
    def obj(eta, mu, g):
        if eta > 0.05:
            return float("inf")
        return (mu - 0.3) ** 2 + np.log10(eta) ** 2
    res = gp_ei_minimize(obj, etas=(0.1, 0.01), mus=(0.0, 0.3),
                         gs=(1, 2), budget=8, seed=1)
    assert np.isfinite(res.best_y)
    assert res.best_x[0] <= 0.05
