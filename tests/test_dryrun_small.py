"""Integration: lower+compile train/prefill/decode for every arch family on a
small forced-device mesh (subprocess, so the 1-device default of the rest of
the test suite is untouched — the production 16x16 / 2x16x16 meshes run via
``python -m repro.launch.dryrun``)."""
import json
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import jax
import jax.numpy as jnp
from repro.configs import get_smoke_config
from repro.configs.base import InputShape, TrainConfig
from repro.launch import steps as ST
from repro.sharding import rules as SH

arch = sys.argv[1]
cfg = get_smoke_config(arch)
mesh = jax.make_mesh((4, 2), ("data", "model"))
results = {}
for shape in (InputShape("train", 32, 8, "train"),
              InputShape("prefill", 64, 8, "prefill"),
              InputShape("decode", 64, 8, "decode")):
    if not ST.supports_shape(cfg, shape):
        results[shape.name] = "skipped"
        continue
    pspecs = ST.params_specs(cfg)
    p_shard = SH.params_shardings(pspecs, cfg, mesh)
    bspecs = ST.batch_specs(cfg, shape)
    b_shard = SH.batch_shardings(bspecs, mesh)
    with mesh, SH.activation_sharding(mesh):
        if shape.kind == "train":
            tc = TrainConfig(grad_accum=2)
            bspecs = ST.batch_specs(cfg, shape, grad_accum=2)
            b_shard = SH.batch_shardings(bspecs, mesh, batch_dim=1)
            mspecs = jax.eval_shape(lambda p: jax.tree.map(
                lambda x: jnp.zeros(x.shape, cfg.dtype("mom")), p), pspecs)
            m_shard = SH.params_shardings(mspecs, cfg, mesh)
            step = ST.make_train_step(cfg, tc, shape, grad_shardings=p_shard)
            c = jax.jit(step, in_shardings=(p_shard, m_shard, b_shard),
                        out_shardings=(p_shard, m_shard, None)
                        ).lower(pspecs, mspecs, bspecs).compile()
        elif shape.kind == "prefill":
            step = ST.make_prefill_step(cfg, shape)
            c = jax.jit(step, in_shardings=(p_shard, b_shard)
                        ).lower(pspecs, bspecs).compile()
        else:
            cspecs = ST.cache_specs_struct(cfg, shape)
            c_shard = SH.cache_shardings(cspecs, cfg, mesh,
                                         batch=shape.global_batch)
            step = ST.make_decode_step(cfg, shape)
            c = jax.jit(step, in_shardings=(p_shard, c_shard, b_shard, None),
                        out_shardings=(None, c_shard)
                        ).lower(pspecs, cspecs, bspecs,
                                jax.ShapeDtypeStruct((), jnp.int32)).compile()
    results[shape.name] = "ok" if c.memory_analysis() is not None else "ok"
import json
print("RESULT:" + json.dumps(results))
"""

# one representative per family keeps the suite fast; the full 10x4x2 matrix
# runs in the dry-run deliverable
FAMILIES = ["qwen2-7b", "grok-1-314b", "mamba2-2.7b", "recurrentgemma-2b",
            "whisper-base", "llama-3.2-vision-90b"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_small_mesh_dryrun(arch):
    proc = subprocess.run([sys.executable, "-c", SCRIPT, arch],
                          capture_output=True, text=True, timeout=420,
                          cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    results = json.loads(line[0][len("RESULT:"):])
    for shape, status in results.items():
        assert status in ("ok", "skipped"), (shape, status)


PLAN_TO_DRYRUN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import json
import numpy as np
from repro import cluster
from repro.core.auto_optimizer import algorithm1

# a 405B-class state does not fit one 4 GB device: the 2-D search must
# return mp > 1
devs = cluster.parse_cluster_spec("8xgpu-g2.2xlarge")
cost = cluster.WorkloadCost(flops_per_example=2e9, bytes_per_example=2e8,
                            grad_bytes=4e6, state_bytes=6e9)
plan = cluster.best_allocation(devs, global_batch=64, t_fc=0.002, cost=cost,
                               g_candidates=(1, 2), mp_candidates=(1, 2))

def runner(state, *, g, mu, eta, steps, probe):
    return state, np.linspace(1.0, 0.1 - 0.05 * mu, steps)

res = algorithm1(runner, None, n_devices=8, epochs=1, epoch_steps=10,
                 probe_steps=5, plan=plan)
assert res.mp == plan.mp and res.g == plan.g, (res.g, res.mp)

# ... and the dryrun host-smoke lane accepts the planned (g, mp) mesh for
# a 405B-class config: 8 host devices split as (g, data, mp)
from repro.launch.dryrun import host_smoke_one
data = 8 // (res.g * res.mp)
out = host_smoke_one("llama3-405b", groups=res.g, data=data, mp=res.mp,
                     verbose=False)
print("RESULT:" + json.dumps({
    "g": res.g, "mp": res.mp, "status": out["status"],
    "mp_leaves": out["mp_sharded_param_leaves"]}))
"""


def test_algorithm1_plan_accepted_by_dryrun():
    """ISSUE acceptance: algorithm1 returns a (g, mp) plan and the dryrun
    host-smoke lane lowers+compiles a 405B-class config through the
    planned ("group","data","mp") mesh."""
    proc = subprocess.run([sys.executable, "-c", PLAN_TO_DRYRUN_SCRIPT],
                          capture_output=True, text=True, timeout=420,
                          cwd="/root/repo")
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT:")]
    assert line, proc.stdout
    res = json.loads(line[0][len("RESULT:"):])
    assert res["status"] == "ok", res
    assert res["mp"] == 2, res
    assert res["mp_leaves"] > 0, res
