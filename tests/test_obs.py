"""Observability subsystem (repro.obs) + the Telemetry facade.

Covers: span tracer semantics (nesting, threads, zero-cost-off), the
typed metric registry and its schema-validated JSONL sink, Chrome-trace
export (spans + metrics + EventTrace tracks), the Telemetry facade's
equivalence with the registry it wraps, engine/pipeline instrumentation
end-to-end, the HE x SE report closing within the CI tolerance, the
bench env stamp + compare.py's --normalize refusal, and the validate
CLI the bench-smoke job gates on.
"""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.workload import mlp_classify
from repro.engine import Engine
from repro.engine.timing import Telemetry, stats_of
from repro.obs import spans
from repro.obs.chrome_trace import (chrome_trace, export_chrome_trace,
                                    load_span_names)
from repro.obs.meta import env_mismatches, run_metadata
from repro.obs.metrics import (MetricRegistry, validate_jsonl,
                               validate_record)

# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_tracer_nesting_depth_and_parent():
    tr = spans.Tracer()
    with tr.span("outer", a=1):
        with tr.span("inner") as sp:
            sp.set(b=2)
    recs = tr.records()
    assert [r.name for r in recs] == ["inner", "outer"]  # commit order
    inner, outer = recs
    assert outer.depth == 0 and outer.parent is None
    assert inner.depth == 1 and inner.parent == outer.index
    assert outer.attrs == {"a": 1} and inner.attrs == {"b": 2}
    assert inner.t0 >= outer.t0 and inner.t1 <= outer.t1
    assert inner.duration_s >= 0
    assert tr.span_names() == ("inner", "outer")


def test_tracer_instant_and_threads():
    tr = spans.Tracer()

    def worker():
        with tr.span("thread-span"):
            pass

    with tr.span("main-span"):
        tr.instant("mark", bucket=3)
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    by_name = {r.name: r for r in tr.records()}
    mark = by_name["mark"]
    assert mark.t0 == mark.t1 and mark.attrs == {"bucket": 3}
    assert mark.depth == 1 and mark.parent == by_name["main-span"].index
    # the worker thread's span is depth 0 on its own stack, not nested
    # under main-span, and carries a different tid
    ts = by_name["thread-span"]
    assert ts.depth == 0 and ts.parent is None
    assert ts.tid != by_name["main-span"].tid


def test_null_tracer_is_shared_noop():
    null = spans.NullTracer()
    assert not null.enabled
    s1 = null.span("a", x=1)
    s2 = null.span("b")
    assert s1 is s2                      # one shared object, no allocation
    with s1 as sp:
        sp.set(anything=True)
    assert null.records() == ()
    assert null.instant("c") is None


def test_install_and_maybe_traced_restore():
    before = spans.current()
    tr = spans.Tracer()
    with spans.install(tr):
        assert spans.current() is tr
        with spans.span("via-module"):
            pass
    assert spans.current() is before
    assert tr.span_names() == ("via-module",)
    with spans.maybe_traced(False) as t:
        assert t is before               # disabled: no fresh tracer
    with spans.maybe_traced(True) as t:
        assert t.enabled and spans.current() is t
    assert spans.current() is before


# ---------------------------------------------------------------------------
# metrics registry + JSONL schema
# ---------------------------------------------------------------------------


def test_registry_kinds_and_collisions():
    reg = MetricRegistry()
    c = reg.counter("steps")
    assert c.inc() == 1 and c.inc(2) == 3
    with pytest.raises(ValueError):
        c.inc(-1)
    reg.gauge("k").set(4)
    assert reg.gauge("k").value == 4.0
    s = reg.series("step_s")
    s.append(0.5)
    assert s.values == [0.5] and s.steps == [0] and s.times[0] is not None
    with pytest.raises(TypeError):
        reg.series("steps")              # name bound to Counter
    with pytest.raises(TypeError):
        reg.counter("step_s")
    assert reg.names() == ("k", "step_s", "steps")
    assert reg.get("missing") is None


def test_registry_notes_dedup():
    reg = MetricRegistry()
    for _ in range(3):
        reg.note("stranded devices: g=4 uses k=1")
    reg.note("other")
    assert reg.notes == ["stranded devices: g=4 uses k=1", "other"]


def test_jsonl_roundtrip(tmp_path):
    reg = MetricRegistry()
    reg.counter("steps").inc(5)
    reg.gauge("mesh_k").set(4)
    sr = reg.series("step_s")
    for i, v in enumerate((0.5, 0.2, 0.3)):
        sr.append(v, step=i)
    reg.note("hello")
    path = tmp_path / "m.jsonl"
    n = reg.to_jsonl(path, run={"arch": "lenet", "batch": 16})
    assert n == validate_jsonl(path) == 1 + 2 + 3 + 1
    back, run = MetricRegistry.from_jsonl(path)
    assert run == {"arch": "lenet", "batch": 16}
    assert back.counter("steps").value == 5
    assert back.gauge("mesh_k").value == 4.0
    assert back.series("step_s").values == [0.5, 0.2, 0.3]
    assert back.series("step_s").times == sr.times     # stamps preserved
    assert back.notes == ["hello"]


def test_schema_validation_rejects_malformed():
    validate_record({"kind": "sample", "name": "x", "index": 0, "t": None,
                     "value": 1.5})
    for bad in (
        {"kind": "nope"},
        {"kind": "sample", "name": "x", "index": 0, "value": 1.0},  # no t
        {"kind": "sample", "name": "x", "index": 0, "t": None,
         "value": 1.0, "extra": 1},
        {"kind": "sample", "name": "", "index": 0, "t": None, "value": 1.0},
        {"kind": "sample", "name": "x", "index": -1, "t": None, "value": 1.0},
        {"kind": "counter", "name": "c", "value": -2},
        {"kind": "counter", "name": "c", "value": True},
        {"kind": "gauge", "name": "g", "value": "fast"},
        {"kind": "meta", "schema": 999, "run": {}},
        {"kind": "meta", "schema": 1, "run": {"x": [1]}},
        "not a dict",
    ):
        with pytest.raises(ValueError):
            validate_record(bad)


def test_validate_jsonl_header_first_and_empty(tmp_path):
    p = tmp_path / "bad.jsonl"
    p.write_text(json.dumps({"kind": "note", "msg": "no header"}) + "\n")
    with pytest.raises(ValueError, match="meta"):
        validate_jsonl(p)
    p.write_text("")
    with pytest.raises(ValueError, match="empty"):
        validate_jsonl(p)


# ---------------------------------------------------------------------------
# Telemetry facade
# ---------------------------------------------------------------------------


def test_telemetry_is_registry_facade():
    reg = MetricRegistry()
    t = Telemetry(skip=1, registry=reg)
    assert t.registry is reg
    t.record(0.5, data_s=0.1)
    t.record(0.2, data_s=0.05)
    # same lists, not copies: one stream, two views
    assert t.step_s is reg.series("step_s").values
    assert t.data_s is reg.series("data_wait_s").values
    assert reg.series("step_s").steps == [0, 1]
    t.note("x")
    t.note("x")
    assert t.notes is reg.notes and t.notes == ["x"]
    assert len(t) == 2


def test_telemetry_median_matches_stats_of():
    t = Telemetry(skip=1)
    for s in (9.0, 0.1, 0.4, 0.2, 0.3):
        t.record(s)
    steady = [0.1, 0.4, 0.2, 0.3]
    # even-length steady sample: the interpolated stats_of median, NOT
    # the old sorted[n//2] upper-median (which would be 0.3)
    assert t.median_step_s() == stats_of(steady).median_s == 0.25
    assert t.stats().min_s == 0.1
    assert t.median_step_s(window=2) == 0.25   # last two: 0.2, 0.3
    assert t.drift(window=2) == t.median_step_s(2) / t.median_step_s()
    with pytest.raises(ValueError):
        t.drift(window=0)


def test_telemetry_skip_edge_semantics():
    # skip >= len(recorded): aggregate over everything rather than nothing
    t = Telemetry(skip=5)
    t.record(0.2)
    t.record(0.4)
    assert t.median_step_s() == pytest.approx(0.3)
    assert t.mean_step_s() == pytest.approx(0.3)
    assert t.summary()["steps"] == 2
    # zero steps recorded: explicit error, not a NaN
    empty = Telemetry()
    for fn in (empty.median_step_s, empty.mean_step_s, empty.stats,
               empty.summary):
        with pytest.raises(ValueError, match="no steps"):
            fn()
    with pytest.raises(ValueError):
        Telemetry(skip=-1)
    with pytest.raises(ValueError):
        t.throughput(0)


# ---------------------------------------------------------------------------
# chrome trace export
# ---------------------------------------------------------------------------


def test_chrome_trace_spans_and_metrics(tmp_path):
    tr = spans.Tracer()
    reg = MetricRegistry()
    with tr.span("engine.run"):
        with tr.span("engine.step", step=0):
            reg.series("loss").append(1.5, step=0)
    doc = chrome_trace(tracer=tr, metrics=reg)
    xs = [e for e in doc["traceEvents"] if e.get("ph") == "X"]
    cs = [e for e in doc["traceEvents"] if e.get("ph") == "C"]
    assert {e["name"] for e in xs} == {"engine.run", "engine.step"}
    assert all(e["pid"] == 0 and e["ts"] >= 0 for e in xs)
    assert cs and cs[0]["args"] == {"loss": 1.5}
    # shared rebased origin: the loss sample lands inside the run span
    run = next(e for e in xs if e["name"] == "engine.run")
    assert run["ts"] <= cs[0]["ts"] <= run["ts"] + run["dur"]
    path = tmp_path / "t.json"
    n = export_chrome_trace(path, tracer=tr, metrics=reg)
    assert n == len(doc["traceEvents"])
    assert load_span_names(path) == ("engine.run", "engine.step")


def test_chrome_trace_event_trace_tracks():
    from repro.exec import EventTrace
    trace = EventTrace(num_groups=2, group=[0, 1, 0], read_version=[0, 0, 1],
                       commit_time=[1.0, 1.5, 2.0])
    events = chrome_trace(event_trace=trace)["traceEvents"]
    bars = [e for e in events if e.get("ph") == "X"]
    assert len(bars) == 3
    assert all(e["pid"] == 1 for e in bars)          # separate clock pid
    assert {e["tid"] for e in bars} == {0, 1}        # one track per group
    # commit 2 read version 1 (created at commit_time[0]=1.0): bar spans
    # the read-to-commit window and its length is the visible staleness
    c2 = next(e for e in bars if e["args"]["commit"] == 2)
    assert c2["ts"] == pytest.approx(1.0 * 1e6)
    assert c2["dur"] == pytest.approx(1.0 * 1e6)
    assert c2["args"]["staleness"] == 1


# ---------------------------------------------------------------------------
# engine + pipeline instrumentation, end to end
# ---------------------------------------------------------------------------


def _run_engine(tracer, steps=6, g=2, batch=32):
    wl = mlp_classify()
    eng = Engine(wl.loss_fn, num_groups=g, lr=0.05, momentum=0.3,
                 tracer=tracer)
    params = wl.init(jax.random.PRNGKey(0))
    mom = jax.tree.map(jnp.zeros_like, params)
    batches = wl.sample_batches(jax.random.PRNGKey(1), steps, batch)
    host = [jax.tree.map(lambda x: np.asarray(x[t]), batches)
            for t in range(steps)]
    eng.run(params, mom, iter(host), steps=steps)
    return eng


def test_engine_run_emits_phase_spans_and_metrics():
    tr = spans.Tracer()
    eng = _run_engine(tr)
    names = set(tr.span_names())
    expected = {"engine.run", "engine.step", "engine.data_wait",
                "engine.dispatch", "engine.block_until_ready",
                "engine.build_step", "data.h2d"}
    assert expected <= names, f"missing {expected - names}"
    if jax.device_count() >= 2:          # tier-1 forces the 8-device lane
        assert "exchange.bucket" in names
        buckets = [r for r in tr.records() if r.name == "exchange.bucket"]
        assert all(r.attrs["bytes"] > 0 for r in buckets)
        # annotated once per built step, not once per round: every
        # bucket index appears exactly once across the whole run
        idxs = [r.attrs["bucket"] for r in buckets]
        assert sorted(idxs) == list(range(len(idxs)))
    reg = eng.telemetry.registry
    assert len(reg.series("step_s")) == 6
    assert len(reg.series("loss")) == 6
    assert len(reg.series("h2d_s")) == 6
    assert all(v > 0 for v in reg.series("h2d_s").values)
    # per-step nesting: 6 data_wait + 6 step spans under one run span
    per = [r for r in tr.records() if r.name == "engine.data_wait"]
    assert len(per) == 6


def test_engine_untraced_records_no_spans_and_same_metrics():
    eng = _run_engine(tracer=None)       # defaults to the null tracer
    assert not eng.tracer.enabled
    assert eng.tracer.records() == ()
    assert len(eng.telemetry) == 6       # metrics flow regardless


def test_engine_replay_staleness_series():
    from repro.exec import EventTrace
    wl = mlp_classify()
    trace = EventTrace.round_robin(num_groups=2, num_commits=6)
    tr = spans.Tracer()
    eng = Engine(wl.loss_fn, strategy="trace-replay", trace=trace,
                 lr=0.05, tracer=tr)
    params = wl.init(jax.random.PRNGKey(0))
    batches = wl.sample_batches(jax.random.PRNGKey(1), 6, wl.batch_size)
    eng.replay(params, batches)
    reg = eng.telemetry.registry
    assert reg.series("staleness").values == [float(s)
                                             for s in trace.staleness]
    assert reg.gauge("replay_max_staleness").value == trace.max_staleness
    assert reg.counter("replay_commits").value == 6
    rep = [r for r in tr.records() if r.name == "engine.replay"]
    assert len(rep) == 1 and rep[0].attrs["commits"] == 6


def test_probe_and_profile_device_emit_spans():
    from repro.cluster.devices import profile_device
    tr = spans.Tracer()
    f = jax.jit(lambda x: x * 2.0)
    x = jnp.ones((8,))
    with spans.install(tr):
        thr = profile_device(lambda *a: f(x), (), batch_size=8, warmup=1,
                             iters=2)
    assert thr > 0
    by_name = {r.name: r for r in tr.records()}
    assert by_name["cluster.profile_device"].attrs["examples_per_s"] == thr
    assert "timing.probe" not in by_name   # profile_device times inline


# ---------------------------------------------------------------------------
# HE x SE report
# ---------------------------------------------------------------------------


def test_hexse_report_within_ci_tolerance():
    """The acceptance loop: recompute T(g, alloc) from a run's own metric
    stream against a plan calibrated from that stream; HE must land
    within 15% of the planner's prediction (CI lane criterion)."""
    from repro.obs.report import calibrated_plan, hexse_report
    eng = _run_engine(tracer=None, steps=8, g=2, batch=32)
    plan = calibrated_plan(eng.telemetry, g=2, global_batch=32)
    rep = hexse_report(eng.telemetry, plan)
    assert rep.within(0.15), rep.render()
    assert rep.g == 2 and rep.steps == 7          # skip=1
    assert rep.he_measured_s == pytest.approx(
        eng.telemetry.median_step_s() / 2)
    assert 0.0 <= rep.data_wait_frac < 1.0
    assert "HE" in rep.render()


def test_hexse_report_roundtrips_through_jsonl(tmp_path):
    from repro.obs.report import calibrated_plan, hexse_report
    eng = _run_engine(tracer=None, steps=6)
    path = tmp_path / "m.jsonl"
    eng.telemetry.registry.to_jsonl(path, run_metadata())
    reg, run = MetricRegistry.from_jsonl(path)
    assert run["jax"] == jax.__version__
    plan = calibrated_plan(reg, g=2, global_batch=32)
    rep = hexse_report(reg, plan)
    assert rep.within(0.15)
    # windowed calibration (the online-rebalance hook) also resolves
    plan_w = calibrated_plan(reg, g=2, global_batch=32, window=3)
    assert plan_w.g == 2


def test_report_errors_on_empty_stream():
    from repro.obs.report import calibrated_plan, measured_step_stats
    reg = MetricRegistry()
    with pytest.raises(ValueError, match="step_s"):
        measured_step_stats(reg)
    with pytest.raises(ValueError, match="calibrate"):
        calibrated_plan(reg, g=2, global_batch=32)


# ---------------------------------------------------------------------------
# env stamp + compare.py refusal
# ---------------------------------------------------------------------------


def test_run_metadata_and_mismatches():
    md = run_metadata(mesh_shape=(2, 4), extra={"arch": "lenet"})
    for key in ("jax", "jaxlib", "backend", "device_count", "device_kind",
                "xla_flags", "python", "machine"):
        assert key in md
    assert md["mesh_shape"] == "2x4" and md["arch"] == "lenet"
    other = dict(md, jax="99.0", device_count=md["device_count"] + 1)
    mism = env_mismatches(md, other)
    assert len(mism) == 2 and any("jax" in m for m in mism)
    assert env_mismatches(md, dict(md)) == ()
    assert env_mismatches(None, md) == ()        # legacy baseline: no stamp
    assert env_mismatches(md, {}) == ()


def test_compare_refuses_env_mismatch(tmp_path):
    import importlib.util
    spec = importlib.util.spec_from_file_location(
        "bench_compare", "benchmarks/compare.py")
    cmp_mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(cmp_mod)

    def bench_doc(env):
        return {"bench": "x", "env": env,
                "rows": [{"g": 2, "step": {"min_us": 100.0,
                                           "median_us": 110.0,
                                           "iqr_us": 5.0, "iters": 5}}]}

    base_env = {"jax": "0.4.37", "backend": "cpu", "device_kind": "cpu",
                "device_count": 8, "xla_flags": ""}
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()
    (base / "BENCH_x.json").write_text(json.dumps(bench_doc(base_env)))
    (fresh / "BENCH_x.json").write_text(
        json.dumps(bench_doc(dict(base_env, device_count=1))))
    ok, reports, md = cmp_mod.compare_dirs(base, fresh, tol=0.15,
                                           normalize=True)
    assert not ok and "env mismatch" in reports["BENCH_x.json"]["error"]
    assert "device_count" in md
    # explicit override compares anyway
    ok, reports, _ = cmp_mod.compare_dirs(base, fresh, tol=0.15,
                                          normalize=True,
                                          allow_env_mismatch=True)
    assert ok and reports["BENCH_x.json"]["regressions"] == 0
    # without --normalize (same-machine mode) the stamp is not consulted
    ok, _, _ = cmp_mod.compare_dirs(base, fresh, tol=0.15, normalize=False)
    assert ok
    # matching envs under --normalize pass as before
    (fresh / "BENCH_x.json").write_text(json.dumps(bench_doc(base_env)))
    ok, _, _ = cmp_mod.compare_dirs(base, fresh, tol=0.15, normalize=True)
    assert ok


# ---------------------------------------------------------------------------
# validate CLI (the bench-smoke gate)
# ---------------------------------------------------------------------------


def test_validate_cli(tmp_path, capsys):
    from repro.obs import validate as V
    tr = spans.Tracer()
    reg = MetricRegistry()
    with tr.span("engine.run"):
        reg.series("step_s").append(0.1, step=0)
    mpath, tpath = tmp_path / "m.jsonl", tmp_path / "t.json"
    reg.to_jsonl(mpath, run_metadata())
    export_chrome_trace(tpath, tracer=tr, metrics=reg)
    assert V.main(["--metrics", str(mpath), "--trace", str(tpath),
                   "--expect-spans", "engine.run",
                   "--expect-series", "step_s"]) == 0
    assert V.main(["--trace", str(tpath),
                   "--expect-spans", "engine.run,engine.missing"]) == 1
    assert V.main(["--metrics", str(mpath),
                   "--expect-series", "not_there"]) == 1
    bad = tmp_path / "bad.jsonl"
    bad.write_text("{}\n")
    assert V.main(["--metrics", str(bad)]) == 1
    capsys.readouterr()
