"""Tests for the perf gate (benchmarks/compare.py): metric extraction
from BENCH_*.json, the IQR-aware regression rule, cross-machine
normalization, and the CLI exit codes CI keys off."""
import importlib.util
import json
from pathlib import Path

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_compare",
    Path(__file__).resolve().parent.parent / "benchmarks" / "compare.py")
compare = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(compare)


def _stats(min_us, iqr_us=0.0):
    return {"min_us": min_us, "median_us": min_us * 1.1,
            "iqr_us": iqr_us, "iters": 15}


def test_extract_metrics_names_rows_by_identity_not_position():
    doc = {
        "bench": "engine",
        "device_count": 8,
        "rows": [
            {"g": 2, "mode": "spmd", "step": _stats(100.0)},
            {"g": 4, "mode": "spmd", "step": _stats(200.0)},
        ],
        "overlap": [
            {"g": 2, "bucket_bytes": 0, "variant": "wholetree",
             "step": _stats(300.0)},
        ],
        "meta": {"note": "not a metric"},
    }
    m = compare.extract_metrics(doc)
    assert len(m) == 3
    key = "[bench=engine,device_count=8].rows[g=2,mode=spmd].step"
    assert m[key]["min_us"] == 100.0
    # reordering the rows must produce the SAME metric names
    doc2 = dict(doc)
    doc2["rows"] = list(reversed(doc["rows"]))
    assert set(compare.extract_metrics(doc2)) == set(m)


def test_identical_passes_and_regression_trips():
    base = {"a": _stats(100.0), "b": _stats(50.0)}
    rep = compare.compare_metrics(base, base)
    assert rep["regressions"] == 0 and not rep["missing"]
    assert all(r["status"] == "ok" for r in rep["rows"])

    fresh = {"a": _stats(100.0), "b": _stats(120.0)}   # 2.4x on b
    rep = compare.compare_metrics(base, fresh)
    assert rep["regressions"] == 1
    bad = [r for r in rep["rows"] if r["status"] == "regression"]
    assert bad[0]["metric"] == "b"


def test_iqr_slack_suppresses_noise_but_not_clean_regressions():
    # 20% over on a noisy metric (IQR covers it): no alarm
    base = {"m": _stats(100.0, iqr_us=30.0)}
    rep = compare.compare_metrics(base, {"m": _stats(120.0, iqr_us=5.0)})
    assert rep["regressions"] == 0
    # the same 20% on a quiet metric trips the 15% default tolerance
    base = {"m": _stats(100.0, iqr_us=1.0)}
    rep = compare.compare_metrics(base, {"m": _stats(120.0, iqr_us=1.0)})
    assert rep["regressions"] == 1
    # fresh-side IQR also widens the gate (shared-CPU box noise)
    rep = compare.compare_metrics(base, {"m": _stats(120.0, iqr_us=40.0)})
    assert rep["regressions"] == 0


def test_improved_new_and_missing_statuses():
    base = {"kept": _stats(100.0), "gone": _stats(10.0)}
    fresh = {"kept": _stats(50.0), "added": _stats(5.0)}
    rep = compare.compare_metrics(base, fresh)
    by = {r["metric"]: r["status"] for r in rep["rows"]}
    assert by == {"kept": "improved", "added": "new"}
    assert rep["missing"] == ["gone"]     # coverage shrink => failure


def test_normalize_forgives_uniform_slowdown_flags_outlier():
    base = {f"m{i}": _stats(100.0) for i in range(5)}
    # uniformly 2x slower machine: no regression under --normalize
    fresh = {f"m{i}": _stats(200.0) for i in range(5)}
    rep = compare.compare_metrics(base, fresh, normalize=True)
    assert rep["speed"] == pytest.approx(2.0)
    assert rep["regressions"] == 0
    # same machine factor, but one metric 3x slower: flagged
    fresh["m3"] = _stats(600.0)
    rep = compare.compare_metrics(base, fresh, normalize=True)
    assert rep["regressions"] == 1
    # without normalization the uniform slowdown (rightly) fails
    rep = compare.compare_metrics(base, {f"m{i}": _stats(200.0)
                                         for i in range(5)})
    assert rep["regressions"] == 5


def _write(d: Path, name: str, doc: dict):
    (d / name).write_text(json.dumps(doc))


def test_cli_exit_codes_and_markdown(tmp_path):
    base_dir, fresh_dir = tmp_path / "base", tmp_path / "fresh"
    base_dir.mkdir(), fresh_dir.mkdir()
    doc = {"bench": "engine", "rows": [{"g": 2, "step": _stats(100.0)}]}
    _write(base_dir, "BENCH_engine.json", doc)
    _write(fresh_dir, "BENCH_engine.json", doc)

    md = tmp_path / "summary.md"
    assert compare.main([str(base_dir), str(fresh_dir),
                         "--markdown", str(md)]) == 0
    assert "BENCH_engine.json" in md.read_text()

    # injected regression: 2x min_us on the one metric -> exit 1 + marker
    bad = {"bench": "engine", "rows": [{"g": 2, "step": _stats(200.0)}]}
    _write(fresh_dir, "BENCH_engine.json", bad)
    md2 = tmp_path / "summary2.md"
    assert compare.main([str(base_dir), str(fresh_dir),
                         "--markdown", str(md2)]) == 1
    assert "REGRESSION" in md2.read_text()

    # fresh emission missing entirely -> exit 1
    (fresh_dir / "BENCH_engine.json").unlink()
    assert compare.main([str(base_dir), str(fresh_dir)]) == 1

    # no baselines at all -> usage error (exit 2)
    assert compare.main([str(fresh_dir), str(base_dir)]) == 2

    # --benches filter selecting nothing -> usage error
    _write(fresh_dir, "BENCH_engine.json", doc)
    assert compare.main([str(base_dir), str(fresh_dir),
                         "--benches", "nope"]) == 2


def test_floor_gate_value_vs_embedded_floor():
    doc = {"bench": "serving",
           "goodput_gate": {"name": "goodput_ratio", "rate": 10.0,
                            "value": 4.9, "floor": 1.3},
           "rows": [{"mode": "continuous", "rate": 10.0,
                     "latency": _stats(100.0)}]}
    floors = compare.extract_floors(doc)
    assert len(floors) == 1
    (name,) = floors
    # an identified gate names itself, so several gates sharing a list
    # (e.g. the per-page decode speedup floors) cannot collapse onto one
    # metric and silently un-gate each other
    assert name == "[bench=serving].goodput_gate[name=goodput_ratio,rate=10.0]"
    # timing extraction must NOT pick up the floor row (and vice versa)
    assert set(compare.extract_metrics(doc)).isdisjoint(floors)

    many = {"bench": "serving",
            "gates": [{"name": "speedup", "page": p, "value": 2.0,
                       "floor": 1.5} for p in (8, 16, 32)]}
    assert len(compare.extract_floors(many)) == 3

    rep = compare.check_floors(floors, floors)
    assert rep["failures"] == 0 and not rep["missing"]
    assert rep["rows"][0]["status"] == "ok"

    bad = {name: {**floors[name], "value": 1.1}}
    rep = compare.check_floors(floors, bad)
    assert rep["failures"] == 1
    assert rep["rows"][0]["status"] == "below-floor"
    # the gate reads the FRESH emission's floor: raising it is a code
    # change, so a fresh floor above the fresh value fails even if the
    # baseline floor would have passed
    tight = {name: {**floors[name], "floor": 5.0}}
    assert compare.check_floors(floors, tight)["failures"] == 1
    # a vanished floor gate is a coverage shrink -> failure
    rep = compare.check_floors(floors, {})
    assert rep["missing"] == [name]


def test_floor_gate_drives_cli_exit_code(tmp_path):
    base_dir, fresh_dir = tmp_path / "base", tmp_path / "fresh"
    base_dir.mkdir(), fresh_dir.mkdir()
    doc = {"bench": "serving",
           "rows": [{"mode": "continuous", "rate": 10.0,
                     "latency": _stats(100.0)}],
           "goodput_gate": {"name": "goodput_ratio", "value": 4.9,
                            "floor": 1.3}}
    _write(base_dir, "BENCH_serving.json", doc)
    _write(fresh_dir, "BENCH_serving.json", doc)
    assert compare.main([str(base_dir), str(fresh_dir)]) == 0

    bad = json.loads(json.dumps(doc))
    bad["goodput_gate"]["value"] = 1.0          # timing rows untouched
    _write(fresh_dir, "BENCH_serving.json", bad)
    md = tmp_path / "summary.md"
    assert compare.main([str(base_dir), str(fresh_dir),
                         "--markdown", str(md)]) == 1
    assert "BELOW FLOOR" in md.read_text()

    gone = json.loads(json.dumps(doc))
    del gone["goodput_gate"]                    # coverage shrink
    _write(fresh_dir, "BENCH_serving.json", gone)
    assert compare.main([str(base_dir), str(fresh_dir)]) == 1


def test_gate_on_committed_baselines_is_self_consistent():
    """The committed BENCH_*.json must pass the gate against themselves —
    guards against committing baselines the extractor cannot parse."""
    repo = Path(__file__).resolve().parent.parent
    files = sorted(repo.glob("BENCH_*.json"))
    if not files:
        pytest.skip("no committed baselines")
    ok, reports, _ = compare.compare_dirs(repo, repo, tol=0.15,
                                          normalize=False)
    assert ok
    for name, rep in reports.items():
        assert rep["shared"] > 0, f"{name}: no metrics extracted"
