"""SSD (Mamba-2) and RG-LRU correctness: chunked/associative-scan forms vs
naive step-by-step recurrences; decode vs forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests report as skipped; rest run
    st = None

from repro.configs import get_smoke_config
from repro.models import rglru as R
from repro.models import ssm as S


def _naive_ssd(x, dt, A, B, C):
    """Direct recurrence h_t = exp(-dt A) h + dt x B^T ; y = h C."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    hst = np.zeros((b, h, p, n))
    ys = []
    x, dt, B, C = map(np.asarray, (x, dt, B, C))
    A = np.asarray(A)
    for t in range(s):
        decay = np.exp(-dt[:, t] * A)[:, :, None, None]
        inject = np.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], B[:, t])
        hst = decay * hst + inject
        ys.append(np.einsum("bhpn,bn->bhp", hst, C[:, t]))
    return np.stack(ys, axis=1), hst


@pytest.mark.parametrize("chunk", [4, 8, 32])
def test_ssd_chunked_matches_naive(chunk):
    key = jax.random.PRNGKey(0)
    b, s, h, p, n = 2, 32, 3, 4, 5
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = jnp.array([0.5, 1.0, 2.0])
    B = jax.random.normal(ks[2], (b, s, n))
    C = jax.random.normal(ks[3], (b, s, n))
    y, hf = S.ssd_scan(x, dt, A, B, C, chunk)
    y_ref, h_ref = _naive_ssd(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), h_ref, rtol=1e-4, atol=1e-4)


if st is None:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_ssd_property():
        pass
else:
    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**30), s=st.sampled_from([8, 16, 24]),
           chunk=st.sampled_from([4, 8]))
    def test_ssd_property(seed, s, chunk):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        b, h, p, n = 1, 2, 3, 4
        x = jax.random.normal(ks[0], (b, s, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        A = jnp.abs(jax.random.normal(ks[2], (h,))) + 0.1
        B = jax.random.normal(ks[3], (b, s, n))
        C = jax.random.normal(ks[0], (b, s, n))
        y, _ = S.ssd_scan(x, dt, A, B, C, chunk)
        y_ref, _ = _naive_ssd(x, dt, A, B, C)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-3,
                                   atol=1e-3)


def test_ssm_decode_matches_forward():
    cfg = get_smoke_config("mamba2-2.7b")
    p = S.init_ssm(jax.random.PRNGKey(0), cfg)
    b, s = 2, cfg.ssm.chunk  # one chunk
    u = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.1
    y_ref, _ = S.ssm_forward(p, u, cfg)
    cache = S.init_ssm_cache(b, cfg)
    ys = []
    for t in range(s):
        y, cache = S.ssm_decode(p, u[:, t:t + 1], cache, cfg)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_ref),
                               rtol=2e-3, atol=2e-3)


def _naive_rglru(a, b0):
    a, b0 = np.asarray(a), np.asarray(b0)
    h = np.zeros_like(b0[:, 0])
    out = []
    for t in range(a.shape[1]):
        h = a[:, t] * h + b0[:, t]
        out.append(h.copy())
    return np.stack(out, axis=1)


def test_rglru_scan_matches_naive():
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    a = jax.nn.sigmoid(jax.random.normal(ks[0], (2, 32, 8)))
    b = jax.random.normal(ks[1], (2, 32, 8))

    def op(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(op, (a, b), axis=1)
    np.testing.assert_allclose(np.asarray(h), _naive_rglru(a, b),
                               rtol=1e-5, atol=1e-5)


def test_rglru_decode_matches_forward():
    cfg = get_smoke_config("recurrentgemma-2b")
    p = R.init_rglru_block(jax.random.PRNGKey(0), cfg)
    b, s = 2, 16
    u = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model)) * 0.1
    y_ref, h_ref = R.rglru_forward(p, u, cfg)
    cache = R.init_rglru_cache(b, cfg)
    ys = []
    for t in range(s):
        y, cache = R.rglru_decode(p, u[:, t:t + 1], cache, cfg)
        ys.append(y)
    y_dec = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(cache["h"]), np.asarray(h_ref),
                               rtol=1e-3, atol=1e-3)
