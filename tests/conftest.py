"""Tier-1 test session config.

Force 8 host CPU devices BEFORE anything imports jax, so the engine's
SPMD ("group", "data") mesh path is a first-class citizen of the default
test run (the multi-device equivalence suite in test_engine.py needs g*k
= 8 real XLA devices; test_dryrun_small already assumed the same count).
An explicit --xla_force_host_platform_device_count in the environment
wins.
"""
import os

_FLAG = "--xla_force_host_platform_device_count"
_flags = os.environ.get("XLA_FLAGS", "")
if _FLAG not in _flags:
    os.environ["XLA_FLAGS"] = f"{_flags} {_FLAG}=8".strip()
