"""MoE layer invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import moe as M


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    return cfg, p


def test_moe_shapes_and_finite(setup):
    cfg, p = setup
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.3
    y, aux = M.moe_forward(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(aux))
    assert float(aux) > 0


def _dense_moe_ref(p, x, cfg):
    """Dropless per-token reference: y = sum_k gate_k * FFN_{e_k}(x) + shared."""
    m = cfg.moe
    b, s, d = x.shape
    logits = x.astype(np.float32) @ np.asarray(p["router"])
    probs = jax.nn.softmax(jnp.asarray(logits), axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, m.top_k)
    gate_vals = gate_vals / gate_vals.sum(-1, keepdims=True)
    wg, wu, wd = (np.asarray(p[k]) for k in ("w_gate", "w_up", "w_down"))
    y = np.zeros((b, s, d), np.float32)
    xn = np.asarray(x)
    for e in range(m.num_experts):
        h = jax.nn.silu(jnp.asarray(xn @ wg[e])) * (xn @ wu[e])
        fe = np.asarray(h @ wd[e])
        for k in range(m.top_k):
            sel = np.asarray(gate_idx[..., k] == e)
            y += fe * (np.asarray(gate_vals[..., k]) * sel)[..., None]
    if "shared" in p:
        sp = p["shared"]
        h = jax.nn.silu(jnp.asarray(xn @ np.asarray(sp["w_gate"]))) \
            * (xn @ np.asarray(sp["w_up"]))
        y += np.asarray(h @ np.asarray(sp["w_down"]))
    return y


def test_moe_matches_dense_reference_when_dropless(setup, monkeypatch):
    """With capacity high enough to be non-binding, the capacity-dispatch
    path must equal the dropless dense reference, for any chunking."""
    cfg, p = setup
    monkeypatch.setattr(M, "CAPACITY_FACTOR", 8.0)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model)) * 0.3
    ref = _dense_moe_ref(p, x, cfg)
    for chunk in (32, 16, 8):
        y, _ = M.moe_forward(p, x, cfg, chunk=chunk)
        # smoke configs compute in bf16 -> loose tolerance
        np.testing.assert_allclose(np.asarray(y), ref, rtol=5e-2, atol=5e-3)


def test_moe_aux_uniform_router_equals_one(setup):
    """GShard aux: uniform routing gives loss == aux_weight * 1.0 (E * sum
    (1/E * 1/E * E) = 1)."""
    cfg, p = setup
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])      # uniform probs
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, cfg.d_model))
    _, aux = M.moe_forward(p, x, cfg)
    # uniform probs: mean_prob = 1/E; frac_tokens sums to top_k
    expected = cfg.moe.router_aux_weight * cfg.moe.top_k
    np.testing.assert_allclose(float(aux), expected, rtol=0.3)


def test_moe_grad_flows(setup):
    cfg, p = setup
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, cfg.d_model)) * 0.3

    def loss(p):
        y, aux = M.moe_forward(p, x, cfg)
        return (y ** 2).mean() + aux
    g = jax.grad(loss)(p)
    norms = jax.tree.map(lambda a: float(jnp.abs(a).sum()), g)
    assert norms["router"] > 0
    assert norms["w_down"] > 0


def test_capacity_cap():
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    assert M.capacity(10**9, cfg) == M.MAX_CAPACITY
    assert M.capacity(1, cfg) == 1
