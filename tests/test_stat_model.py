"""stat_model penalty bookkeeping: explicit inf/None semantics for
degenerate SE data (the old truthiness test silently collapsed se_iters=0
to "unknown" and a zero baseline to a division error)."""
import math

import numpy as np
import pytest

from repro.core.stat_model import (TradeoffPoint, iterations_to_loss,
                                   penalties, penalty_ratio,
                                   predict_se_penalty)


def _pt(g, he, se):
    return TradeoffPoint(g=g, mu=0.9, eta=0.1, he_time=he, se_iters=se)


def test_penalty_ratio_semantics():
    assert penalty_ratio(None, 10) is None          # unknown point
    assert penalty_ratio(10, None) is None          # unknown baseline
    assert penalty_ratio(10, 0) == math.inf         # baseline instant
    assert penalty_ratio(0, 0) == 1.0               # both instant
    assert penalty_ratio(0, 10) == 0.0              # point instant
    assert penalty_ratio(30, 10) == 3.0


def test_penalties_zero_se_baseline_gives_inf_not_crash():
    pts = {1: _pt(1, 1.0, 0), 4: _pt(4, 0.5, 20)}
    out = penalties(pts)
    assert out[4]["P_SE"] == math.inf
    assert out[4]["P_total"] == math.inf
    assert out[1]["P_SE"] == 1.0                    # 0/0: equally instant
    assert out[1]["P_HE"] == 1.0


def test_penalties_zero_se_point_is_zero_not_none():
    pts = {1: _pt(1, 1.0, 100), 2: _pt(2, 0.6, 0)}
    out = penalties(pts)
    assert out[2]["P_SE"] == 0.0
    assert out[2]["P_total"] == 0.0


def test_penalties_missing_se_is_none():
    pts = {1: _pt(1, 1.0, 100), 8: _pt(8, 0.2, None)}
    out = penalties(pts)
    assert out[8]["P_SE"] is None
    assert out[8]["P_total"] is None
    assert out[8]["P_HE"] == pytest.approx(0.2)


def test_penalties_requires_sync_baseline():
    with pytest.raises(ValueError):
        penalties({2: _pt(2, 0.5, 10)})


def test_total_time_and_iterations_to_loss():
    assert _pt(1, 0.5, 40).total_time == 20.0
    assert _pt(1, 0.5, None).total_time is None
    assert _pt(1, 0.5, 0).total_time == 0.0         # instant, not unknown
    losses = np.concatenate([np.linspace(2.0, 0.4, 50), np.full(10, 0.4)])
    it = iterations_to_loss(losses, 0.5)
    assert it is not None and 0 < it < 60
    assert iterations_to_loss([], 0.5) is None
    assert iterations_to_loss([2.0, 1.9], 0.5) is None


def test_predict_se_penalty_shape():
    assert predict_se_penalty(1, 0.9) == 1.0
    assert predict_se_penalty(4, 0.9) == 1.0        # implicit 0.75 < 0.9
    assert predict_se_penalty(32, 0.9) > 1.0        # implicit past optimum
    assert (predict_se_penalty(64, 0.9, sharpness=8.0)
            > predict_se_penalty(64, 0.9, sharpness=2.0))
