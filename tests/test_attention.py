"""Attention correctness: chunked (flash-semantics) vs full, sliding window,
decode-with-cache vs full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests report as skipped; rest run
    st = None

from repro.configs.base import ArchConfig
from repro.models import layers as L


def _cfg(h=4, kv=2, hd=16, window=None, bias=False):
    return ArchConfig(name="t", arch_type="dense", num_layers=1, d_model=h * hd,
                      num_heads=h, num_kv_heads=kv, head_dim=hd, d_ff=32,
                      vocab_size=64, sliding_window=window, qkv_bias=bias,
                      compute_dtype="float32", remat=False)


def _qkv(key, b, s, h, kv, hd):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kv, hd))
    v = jax.random.normal(ks[2], (b, s, kv, hd))
    return q, k, v


@pytest.mark.parametrize("window", [None, 7, 32])
@pytest.mark.parametrize("kv", [1, 2, 4])
def test_chunked_matches_full(window, kv):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 64, 4, kv, 16)
    ref = L.full_attention(q, k, v, causal=True, window=window)
    out = L.chunked_attention(q, k, v, causal=True, window=window, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


if st is None:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_chunked_matches_full_property():
        pass
else:
    @settings(max_examples=20, deadline=None)
    @given(s=st.sampled_from([16, 32, 48]),
           h=st.sampled_from([2, 4]),
           chunk=st.sampled_from([8, 16]),
           seed=st.integers(0, 2**30))
    def test_chunked_matches_full_property(s, h, chunk, seed):
        q, k, v = _qkv(jax.random.PRNGKey(seed), 1, s, h, h, 8)
        ref = L.full_attention(q, k, v, causal=True)
        out = L.chunked_attention(q, k, v, causal=True, kv_chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("window", [None, 8])
def test_decode_matches_forward(window):
    """Token-by-token decode with ring cache must reproduce the causal
    forward logits at each position."""
    cfg = _cfg(window=window)
    key = jax.random.PRNGKey(1)
    p = L.init_attention(key, cfg)
    b, s = 2, 24
    x = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.d_model))
    ref, _ = L.attention_forward(p, x, cfg, window=window)
    cache = L.init_attn_cache(b, cfg, s, window)
    outs = []
    for t in range(s):
        y, cache = L.attention_decode(p, x[:, t:t + 1], cache, jnp.int32(t),
                                      cfg, window=window)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_locality():
    """With window W, output at position i must not depend on tokens < i-W+1."""
    cfg = _cfg(window=4)
    p = L.init_attention(jax.random.PRNGKey(3), cfg)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, cfg.d_model))
    y1, _ = L.attention_forward(p, x, cfg, window=4)
    x2 = x.at[:, 0:8, :].set(jax.random.normal(jax.random.PRNGKey(5),
                                               (1, 8, cfg.d_model)))
    y2, _ = L.attention_forward(p, x2, cfg, window=4)
    # positions >= 12 see only tokens >= 9, untouched by the perturbation
    np.testing.assert_allclose(np.asarray(y1[:, 12:]), np.asarray(y2[:, 12:]),
                               rtol=1e-5, atol=1e-5)


def test_rope_relative():
    """RoPE: q·k depends only on relative offset."""
    hd = 16
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 1, hd))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, hd))
    def dot_at(pq, pk):
        qr = L.rope(q, jnp.array([[pq]]), 10000.0)
        kr = L.rope(k, jnp.array([[pk]]), 10000.0)
        return float(jnp.sum(qr * kr))
    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-4
    assert abs(dot_at(5, 3) - dot_at(5, 4)) > 1e-6  # actually position-sensitive
