"""Property tests for sharding/rules.py — the engine's mp param-spec
derivation (the mp-axis PR's rules contract).

Invariants pinned here:
  * every spec emitted by engine_param_specs / param_spec / auto_spec
    divides its leaf shape (no invalid sharding ever escapes the rules);
  * leading lax.scan stacking dims are never sharded;
  * engine specs only ever use the mp axis — "group"/"data" stay
    replicated for params (the grouped update runs identically on every
    worker of every group);
  * the TENSOR_PREF fallback never silently replicates a shardable
    matmul weight;
  * explicit (path-regex, PartitionSpec) rules win over the table, and a
    non-dividing explicit rule raises instead of emitting a bad spec;
  * default_axes resolves tensor/fsdp names from every mesh flavor
    (legacy "model" naming, engine "mp" naming, pure-data meshes).

Rule derivation touches no devices (specs are pure functions of shapes
and mesh axis sizes), so most tests drive a lightweight mesh stand-in —
only the real-mesh resolution test needs the forced 8-device pool.
"""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import (TENSOR_PREF, _match_rule, auto_spec,
                                  default_axes, engine_param_specs,
                                  spec_mp_dim)

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs 8 host devices (tests/conftest.py forces them in tier-1)")


def _mesh(**axes):
    """Shape-only mesh stand-in: rule derivation reads mesh.shape only."""
    return types.SimpleNamespace(shape=dict(axes))


MESH_MP2 = _mesh(group=2, data=2, mp=2)
MESH_MP4 = _mesh(group=1, data=2, mp=4)


def _sds(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def _axes_used(spec):
    return {a for e in tuple(spec)
            for a in (e if isinstance(e, tuple) else (e,)) if a is not None}


def _spec_divides(spec, shape, mesh):
    for d, ax in enumerate(tuple(spec)):
        if ax is None:
            continue
        size = int(np.prod([mesh.shape[a] for a in
                            (ax if isinstance(ax, tuple) else (ax,))]))
        if d >= len(shape) or shape[d] % size:
            return False
    return True


def _check_leaf_spec(name, shape, spec, mesh, stacked=0):
    assert len(tuple(spec)) == len(shape), (name, shape, spec)
    assert _spec_divides(spec, shape, mesh), (name, shape, spec)
    assert _axes_used(spec) <= {"mp"}, (name, shape, spec)
    for d in range(stacked):
        assert tuple(spec)[d] is None, (name, shape, spec)


def test_engine_specs_always_divide_and_use_only_mp():
    """Exhaustive sweep: every TENSOR_PREF name plus unknown names, over
    pseudo-random shapes/ndims and mp in {2, 4} — emitted specs always
    divide, never touch group/data, never shard a scan-stack dim."""
    rng = np.random.default_rng(0)
    names = list(TENSOR_PREF) + ["mystery", "alpha", "h0", "scale"]
    dims = [1, 2, 3, 4, 5, 6, 8, 12, 16, 32, 48]
    for mesh in (MESH_MP2, MESH_MP4):
        for trial in range(200):
            name = names[int(rng.integers(len(names)))]
            ndim = int(rng.integers(1, 5))
            shape = tuple(int(rng.choice(dims)) for _ in range(ndim))
            specs = engine_param_specs({name: _sds(shape)}, mesh)
            _check_leaf_spec(name, shape, specs[name], mesh)


def test_engine_specs_never_shard_scan_stack_dims():
    """Params under a "blocks" path carry a leading lax.scan stacking dim
    that must stay unsharded whatever the name table says."""
    mesh = MESH_MP2
    params = {"blocks": {"w_up": _sds((4, 64, 256)),
                         "wq": _sds((4, 64, 64)),
                         "mystery": _sds((4, 32, 48))}}
    specs = engine_param_specs(params, mesh)
    for name, leaf in params["blocks"].items():
        spec = specs["blocks"][name]
        _check_leaf_spec(name, leaf.shape, spec, mesh, stacked=1)
        assert spec_mp_dim(spec, "mp") not in (None, 0), (name, spec)


def test_tensor_pref_fallback_never_replicates_shardable_weight():
    """A >=2-D matmul weight whose every dim divides the mp axis must come
    out sharded — silent replication of shardable weights is the memory
    regression the big configs died on."""
    mesh = MESH_MP2
    names = ["wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "tok",
             "unembed", "router", "in_proj", "out_proj", "w_rec_in",
             "mystery_weight"]
    for name in names:
        specs = engine_param_specs({name: _sds((48, 64))}, mesh)
        spec = specs[name]
        _check_leaf_spec(name, (48, 64), spec, mesh)
        assert spec_mp_dim(spec, "mp") is not None, (name, spec)
    # 1-D leaves (norm scales, biases) are NOT matmul weights: replicated
    specs = engine_param_specs({"scale": _sds((64,))}, mesh)
    assert spec_mp_dim(specs["scale"], "mp") is None


def test_explicit_rules_win_and_validate_divisibility():
    mesh = MESH_MP2
    # the table would shard wq on dim 1; an explicit rule forces dim 0
    rules = (((r"enc", r"wq"), P("mp", None)),)
    specs = engine_param_specs({"enc": {"wq": _sds((8, 6))}}, mesh,
                               rules=rules)
    assert tuple(specs["enc"]["wq"]) == ("mp", None)
    # first match wins over later rules and over the table
    rules2 = (((r"wq",), P()), ((r"w.",), P("mp", None)))
    specs2 = engine_param_specs({"wq": _sds((8, 6))}, mesh, rules=rules2)
    assert tuple(specs2["wq"]) == ()
    # a rule that does not divide the leaf raises instead of emitting
    bad = (((r"wq",), P(None, "mp")),)
    with pytest.raises(ValueError, match="does not divide"):
        engine_param_specs({"wq": _sds((8, 5))}, mesh, rules=bad)


def test_match_rule_contiguous_windows_full_match():
    assert _match_rule((r"blocks", r"w\d"), ("m", "blocks", "w1"))
    assert not _match_rule((r"blocks", r"w1"), ("blocks", "x", "w1"))
    assert _match_rule((r"w1",), ("a", "b", "w1"))
    assert not _match_rule((r"w",), ("w1",))        # full match, not prefix
    assert not _match_rule((r"a", r"b"), ("b",))    # window longer than keys


def test_auto_spec_trailing_most_divisible():
    assert tuple(auto_spec((8,), 2, axis="mp")) == (None,)
    assert tuple(auto_spec((6, 8), 2, axis="mp")) == (None, "mp")
    assert tuple(auto_spec((6, 7), 2, axis="mp")) == ("mp", None)
    assert tuple(auto_spec((5, 7), 2, axis="mp")) == (None, None)
    assert tuple(auto_spec((4, 6, 8), 2, axis="mp",
                           num_stack_dims=1)) == (None, None, "mp")
    # stacked leaf with a 1-D body replicates
    assert tuple(auto_spec((4, 8), 2, axis="mp",
                           num_stack_dims=1)) == (None, None)
    assert tuple(auto_spec((6, 8), 1, axis="mp")) == (None, None)


def test_spec_mp_dim():
    assert spec_mp_dim(P(None, "mp"), "mp") == 1
    assert spec_mp_dim(P(("data", "mp"), None), "mp") == 0
    assert spec_mp_dim(P("data", None), "mp") is None
    assert spec_mp_dim(P(), "mp") is None


def test_default_axes_all_mesh_flavors():
    assert default_axes(_mesh(data=16, model=16)) == ("model", ("data",))
    assert default_axes(_mesh(pod=2, data=16, model=16)) == \
        ("model", ("pod", "data"))
    assert default_axes(_mesh(group=2, data=2, mp=2)) == ("mp", ("data",))
    assert default_axes(_mesh(group=2, data=4)) == (None, ("data",))


@needs8
def test_default_axes_on_real_meshes():
    """The real mesh constructors resolve to the same axis roles the
    stand-ins pin above (engine group mesh, host-smoke mesh, legacy test
    mesh)."""
    from repro.launch.mesh import (make_group_mesh, make_host_smoke_mesh,
                                   make_test_mesh)
    assert default_axes(make_group_mesh(2, 2, 2)) == ("mp", ("data",))
    assert default_axes(make_host_smoke_mesh(data=4, mp=2)) == \
        ("mp", ("data",))
    assert default_axes(make_test_mesh(2, 2)) == ("model", ("data",))


try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property tests report as skipped; rest run
    st = None

if st is None:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_engine_specs_divide_property():
        pass
else:
    _NAMES = list(TENSOR_PREF) + ["mystery", "alpha", "h0"]

    @given(st.sampled_from(_NAMES),
           st.lists(st.sampled_from([1, 2, 3, 4, 5, 6, 8, 12, 16, 32, 48]),
                    min_size=1, max_size=4),
           st.sampled_from([1, 2, 4]),
           st.booleans())
    @settings(max_examples=150, deadline=None)
    def test_engine_specs_divide_property(name, shape, mp, stacked):
        """Hypothesis sweep of the same invariants: specs divide, only the
        mp axis appears, stack dims stay unsharded, mp=1 replicates."""
        mesh = _mesh(group=2, data=2, mp=mp)
        shape = tuple(([4] if stacked else []) + shape)
        tree = ({"blocks": {name: _sds(shape)}} if stacked
                else {name: _sds(shape)})
        specs = engine_param_specs(tree, mesh)
        spec = specs["blocks"][name] if stacked else specs[name]
        _check_leaf_spec(name, shape, spec, mesh,
                         stacked=1 if stacked else 0)
        if mp == 1:
            assert _axes_used(spec) == set()
