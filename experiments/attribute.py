"""Attribute trip-weighted collective bytes of a (arch, shape) lowering to
JAX op names — the hillclimb profiling tool (dry-run profile, no hardware).

``--replay-compare`` instead runs the predicted-vs-replayed validation
table: for each g, the analytic staleness / implicit-momentum / SE-penalty
predictions next to what actually falls out of *executing* SGD along the
simulator's event trace (repro.exec)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import sys
sys.path.insert(0, "/root/repo/src")
import argparse
import collections
import re
import jax
import jax.numpy as jnp
from repro.configs import get_config, INPUT_SHAPES
from repro.configs.base import TrainConfig
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import GRAD_ACCUM
from repro.sharding import rules as SH
import repro.launch.hlo_parse as HP

def compile_pair(arch, shape_name, accum=None):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh()
    a = accum if accum is not None else (GRAD_ACCUM.get(arch, 1) if shape.kind == "train" else 1)
    tc = TrainConfig(grad_accum=a)
    pspecs = ST.params_specs(cfg)
    p_shard = SH.params_shardings(pspecs, cfg, mesh)
    bspecs = ST.batch_specs(cfg, shape, grad_accum=a)
    b_shard = SH.batch_shardings(bspecs, mesh, batch_dim=1 if a > 1 else 0)
    with mesh, SH.activation_sharding(mesh):
        if shape.kind == "train":
            mspecs = jax.eval_shape(lambda p: jax.tree.map(lambda x: jnp.zeros(x.shape, cfg.dtype("mom")), p), pspecs)
            m_shard = SH.params_shardings(mspecs, cfg, mesh)
            step = ST.make_train_step(cfg, tc, shape, grad_shardings=p_shard)
            return jax.jit(step, in_shardings=(p_shard, m_shard, b_shard),
                out_shardings=(p_shard, m_shard, SH.replicated(mesh))).lower(pspecs, mspecs, bspecs).compile()
        elif shape.kind == "prefill":
            step = ST.make_prefill_step(cfg, shape)
            return jax.jit(step, in_shardings=(p_shard, b_shard)).lower(pspecs, bspecs).compile()
        else:
            cspecs = ST.cache_specs_struct(cfg, shape)
            c_shard = SH.cache_shardings(cspecs, cfg, mesh, batch=shape.global_batch)
            step = ST.make_decode_step(cfg, shape)
            return jax.jit(step, in_shardings=(p_shard, c_shard, b_shard, SH.replicated(mesh)),
                out_shardings=(SH.replicated(mesh), c_shard)).lower(
                pspecs, cspecs, bspecs, jax.ShapeDtypeStruct((), jnp.int32)).compile()

def attribute(txt, top=12):
    comps = HP.split_computations(txt)
    entry = re.search(r"ENTRY\s+%?([\w.\-]+)", txt).group(1)
    mult = {n: 0.0 for n in comps}
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        c = order[i]
        i += 1
        comp = comps[c]
        base = mult[c]
        for line in comp.lines:
            body = re.search(r"body=%?([\w.\-]+)", line)
            cond = re.search(r"condition=%?([\w.\-]+)", line)
            if re.search(r"\bwhile\(", line) and body and cond and body.group(1) in comps:
                t = HP._find_trip_count(comps[cond.group(1)]) if cond.group(1) in comps else 1
                for callee, f in ((body.group(1), t), (cond.group(1), t+1)):
                    if callee in comps:
                        mult[callee] += base*f
                        if callee not in seen:
                            seen.add(callee)
                            order.append(callee)
                continue
            cm = HP._CALL_RE.search(line)
            if cm:
                for callee in re.split(r",\s*", cm.group(1)):
                    callee = callee.lstrip("%")
                    if callee in comps:
                        mult[callee] += base
                        if callee not in seen:
                            seen.add(callee)
                            order.append(callee)
    agg = collections.Counter()
    for name, comp in comps.items():
        w = mult.get(name, 0)
        if w <= 0:
            continue
        for line in comp.lines:
            m = re.search(r"\b(all-reduce|all-gather|all-to-all|collective-permute|reduce-scatter)(?:-start)?\(", line)
            if not m or "-done(" in line:
                continue
            d = HP._DEF_RE.match(line)
            if not d:
                continue
            rs = HP._SHAPE_RE.match(d.group(2))
            b = HP._shape_bytes(*rs.groups()) if rs else 0
            meta = re.search(r'op_name="([^"]+)"', line)
            nm = (meta.group(1) if meta else "?")
            agg[(m.group(1), rs.group(2)[:28] if rs else "?", nm[-70:])] += w*b
    for (op, shp, name), b in agg.most_common(top):
        print(f"{b/2**30:9.1f} GiB  {op:18s} [{shp}] ...{name}")

def replay_compare(gs=(1, 2, 4, 8), steps=400, momentum_runs=800, seed=0):
    """Predicted vs replayed staleness / implicit momentum / SE per g.

    Columns: analytic round-robin staleness (g-1) vs the exponential-
    service simulator's trace mean; Theorem 1's 1-1/g vs the momentum
    fitted from replayed trajectories; analytic P_SE vs the penalty
    measured by replaying an MLP workload along each g's trace
    (stat_model.measured_se_from_replay).
    """
    import numpy as np
    from repro.core import queue_sim
    from repro.core.implicit_momentum import (implicit_momentum,
                                              measure_effective_momentum)
    from repro.core.stat_model import (measured_se_from_replay,
                                       predict_se_penalty)
    from repro.core.workload import mlp_classify
    from repro.engine import Engine
    from repro.exec import replayed_momentum_experiment

    gs = tuple(sorted(set(gs) | {1}))   # P_SE normalizes to the sync run
    wl = mlp_classify()
    params = wl.init(jax.random.PRNGKey(seed))
    batches = wl.sample_batches(jax.random.PRNGKey(seed + 1), steps,
                                wl.batch_size)
    curves, sim_staleness = {}, {}
    for g in gs:
        _, trace = queue_sim.simulate(g=g, t_conv=1.0, t_fc=1e-2,
                                      iters=steps, exponential=True,
                                      seed=seed, return_trace=True)
        # drop warmup like SimResult.mean_staleness does
        sim_staleness[g] = float(trace.staleness[len(trace) // 10:].mean())
        # the same engine replay strategy train.py drives
        eng = Engine(wl.loss_fn, strategy="trace-replay", trace=trace,
                     lr=0.05, momentum=0.0, replay_impl="scan")
        _, losses = eng.replay(params, batches)
        curves[g] = np.asarray(losses)
    # target: the loss the sync run reaches at 60% of the budget
    k = max(1, int(0.6 * steps))
    target = float(np.convolve(curves[min(gs)], np.ones(5) / 5,
                               mode="valid")[:k].min())
    se = measured_se_from_replay(curves, target)
    print("g  S_pred S_sim   mu_pred mu_replay   P_SE_pred P_SE_replay"
          "  se_iters")
    for g in gs:
        if g == 1:
            mu_meas = 0.0
        else:
            traj = replayed_momentum_experiment(
                g, eta=0.2, steps=300, runs=momentum_runs, seed=seed)
            w = traj[3:]
            keep = np.nonzero(np.abs(w) >= 1e-3)[0]
            if keep.size:
                w = w[:keep[-1] + 1]
            mu_meas = measure_effective_momentum(w[:, None], w[:, None],
                                                 lr=0.2, fit_lr=True)
        row = se[g]
        pse = row["P_SE"]
        print(f"{g:<3d}{g - 1:6d} {sim_staleness[g]:6.2f}  "
              f"{implicit_momentum(g):7.3f} {mu_meas:9.3f}  "
              f"{predict_se_penalty(g, 0.9):9.2f} "
              f"{pse if pse is None else f'{pse:11.2f}'}  "
              f"{row['se_iters']}")
    return se


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("arch", nargs="?")
    ap.add_argument("shape", nargs="?")
    ap.add_argument("--accum", type=int)
    ap.add_argument("--replay-compare", action="store_true",
                    help="predicted-vs-replayed staleness/SE table "
                         "instead of HLO attribution")
    ap.add_argument("--gs", type=str, default="1,2,4,8")
    args = ap.parse_args()
    if args.replay_compare:
        replay_compare(gs=tuple(int(x) for x in args.gs.split(",")))
    else:
        if not (args.arch and args.shape):
            ap.error("arch and shape are required without --replay-compare")
        c = compile_pair(args.arch, args.shape, args.accum)
        attribute(c.as_text())
