"""Regenerate EXPERIMENTS.md baseline tables from dryrun JSONs."""
import glob
import json
from pathlib import Path

rows = {}
for f in glob.glob("/root/repo/experiments/dryrun/*.json"):
    d = json.load(open(f))
    rows[(d["arch"], d["shape"], d["mesh"])] = d

ARCHS = sorted({k[0] for k in rows})
SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]

def fmt_num(x, unit=1e-3, nd=1):
    return f"{x/unit:.{nd}f}"

out = []
out.append("### Single-pod (16x16 = 256 chips) baseline roofline — all 40 pairs\n")
out.append("| arch | shape | status | bottleneck | t_comp (ms) | t_mem (ms) | t_coll (ms) | step (ms) | useful (6ND/HLO) | mem/chip (GiB) | compile (s) |")
out.append("|---|---|---|---|---|---|---|---|---|---|---|")
for a in ARCHS:
    for s in SHAPES:
        d = rows.get((a, s, "16x16"))
        if d is None:
            continue
        if d["status"] != "ok":
            out.append(f"| {a} | {s} | {d['status']} | — | — | — | — | — | — | — | — |")
            continue
        r = d["roofline"]
        uf = d.get("useful_flops_frac")
        mem = d["memory"]["peak_per_chip_est"]/2**30
        out.append(f"| {a} | {s} | ok | {r['bottleneck']} | {r['t_compute']*1e3:.2f} | {r['t_memory']*1e3:.2f} | {r['t_collective']*1e3:.2f} | {r['step_time']*1e3:.2f} | {uf:.3f} | {mem:.2f} | {d['compile_s']} |")
out.append("")
out.append("### Multi-pod (2x16x16 = 512 chips) — lower+compile proof (deliverable e)\n")
out.append("| arch | shape | status | step (ms) | mem/chip (GiB) | collective bytes/chip (GB) | compile (s) |")
out.append("|---|---|---|---|---|---|---|")
for a in ARCHS:
    for s in SHAPES:
        d = rows.get((a, s, "2x16x16"))
        if d is None:
            continue
        if d["status"] != "ok":
            out.append(f"| {a} | {s} | {d['status']} | — | — | — | — |")
            continue
        r = d["roofline"]
        mem = d["memory"]["peak_per_chip_est"]/2**30
        out.append(f"| {a} | {s} | ok | {r['step_time']*1e3:.2f} | {mem:.2f} | {r['collective_bytes']/1e9:.1f} | {d['compile_s']} |")
Path("/root/repo/experiments/baseline_tables.md").write_text("\n".join(out) + "\n")
print("\n".join(out[:14]))
print("... rows:", len(out))
