"""Benchmark harness — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV rows (us_per_call = wall time of the
benchmarked operation on this CPU container; derived = the paper-relevant
metric).

  fig4_lowering_blocksize   paper Fig. 4  (b_p batching sweep, TPU: VMEM model)
  fig5_he_model             paper Fig. 5b (HE model vs discrete-event sim)
  fig6_implicit_momentum    paper Fig. 6  (measured vs 1-1/g)
  fig7_tradeoff             paper Fig. 7  (HE x SE x total time vs g)
  fig13_momentum_lesion     paper Fig. 13 (tuned mu vs default 0.9 at g=4)
  fig23_batch_size          paper Fig. 23 (epochs-to-converge vs batch size)
  table_optimizer_vs_bayes  paper §VI-C2  (Algorithm 1 vs GP-EI budget)
  roofline_table            EXPERIMENTS.md §Roofline (from dry-run JSONs)
"""
from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

import os
import sys

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.engine import timing  # noqa: E402  (one clock repo-wide)
from repro.engine.timing import monotonic  # noqa: E402
from repro.obs.meta import run_metadata  # noqa: E402  (BENCH env stamp)


def _row(name, us, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _timeit(fn, warmup: int = 1, iters: int = 5) -> timing.TimeStats:
    """min/median/IQR wall seconds of ``fn()`` over ``iters`` runs, after
    ``warmup`` untimed calls (absorbs jit compilation). Every BENCH_*.json
    emitter records all three (``TimeStats.row``): median alone cannot
    distinguish real effects from noise on a shared-CPU box; min is the
    noise-robust point estimate, IQR the spread certificate. Speedups are
    computed from min for that reason."""
    return timing.probe(fn, warmup=warmup, iters=iters)


def _timeit_interleaved(fns: dict, warmup: int = 1, iters: int = 9) -> dict:
    """Time several thunks round-robin: one sample of each per round, so a
    noisy scheduler window degrades every contestant equally instead of
    poisoning one contestant's whole block. The right tool whenever two
    implementations are compared head-to-head. Returns {name: TimeStats}."""
    for fn in fns.values():
        for _ in range(warmup):
            jax.block_until_ready(fn())
    samples = {name: [] for name in fns}
    for _ in range(iters):
        for name, fn in fns.items():
            t0 = monotonic()
            jax.block_until_ready(fn())
            samples[name].append(monotonic() - t0)
    return {name: timing.stats_of(s) for name, s in samples.items()}


# ---------------------------------------------------------------------------

def fig4_lowering_blocksize():
    """Paper Fig. 4: GEMM speed & memory vs b_p. On TPU the tradeoff is VMEM
    footprint vs MXU tile alignment; interpret-mode wall time included for
    relative CPU sanity only."""
    from repro.kernels.lowering_conv import choose_tiles, ops as lc, vmem_bytes
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 16, 16, 8))
    w = jax.random.normal(jax.random.PRNGKey(1), (3, 3, 8, 32))
    b, h, wd, cin = x.shape
    kh, kw, _, cout = w.shape
    ho, wo = (h - kh) + 1, (wd - kw) + 1           # stride 1, VALID
    for bp in (1, 2, 4, 8, 16):
        us = _timeit(lambda: lc.lowering_conv(x, w, stride=1, bp=bp, rb=7,
                                              interpret=True),
                     warmup=1, iters=3).median_s * 1e6
        bp_c, rb_c = choose_tiles(b, ho, bp, 7)    # tiles the kernel ran
        vm = vmem_bytes(bp=bp_c, rb=rb_c, h=h, w=wd, cin=cin, kh=kh, kw=kw,
                        cout=cout)
        gemm_m = bp_c * rb_c * wo
        aligned = "ok" if gemm_m % 128 == 0 else f"pad{128 - gemm_m % 128}"
        _row(f"fig4_bp{bp}", us,
             f"bp={bp_c};rb={rb_c};vmem_kB={vm//1024};gemm_M={gemm_m};"
             f"mxu={aligned}")


def fig5_he_model():
    from repro.core import hardware_model as hm
    from repro.core import queue_sim
    ph = hm.PhaseTimes(t_conv_compute_1=1.0, t_fc=0.08, conv_grad_bytes=0.0)
    for g in (1, 2, 4, 8, 16, 32):
        t0 = monotonic()
        sim = queue_sim.simulate(g=g, t_conv=1.0 / (32 // g), t_fc=0.08,
                                 iters=2000, exponential=False)
        us = (monotonic() - t0) * 1e6
        pred = hm.he_time_per_iteration(g, 32, ph)
        _row(f"fig5_he_g{g}", us,
             f"pred={pred:.4f};sim={sim.time_per_iteration:.4f};"
             f"err={abs(pred-sim.time_per_iteration)/pred:.1%}")


def fig6_implicit_momentum():
    from repro.core.implicit_momentum import (async_quadratic_sim,
                                              fit_ar2_momentum,
                                              implicit_momentum)
    for g in (2, 4, 8, 16):
        t0 = monotonic()
        traj = async_quadratic_sim(g=g, eta=0.2, steps=250, runs=1500)
        mu, eta_eff = fit_ar2_momentum(traj[3:])
        us = (monotonic() - t0) * 1e6
        _row(f"fig6_mom_g{g}", us,
             f"measured={mu:.3f};theory={implicit_momentum(g):.3f};"
             f"eta_eff={eta_eff:.4f}")


def _se_iters(wl, params, g, mu, eta, steps, target):
    from repro.core.async_sgd import delayed_sgd_run
    from repro.core.stat_model import iterations_to_loss
    batches = wl.sample_batches(jax.random.PRNGKey(1), steps, wl.batch_size)
    _, losses, _ = delayed_sgd_run(wl.loss_fn, params, batches,
                                   staleness=g - 1, lr=eta, momentum=mu)
    return iterations_to_loss(np.asarray(losses), target)


def fig7_tradeoff():
    """HE x SE x total-time vs number of groups, on the CNN workload.
    HE from the analytic model (TPU-style constants), SE measured by real
    delayed-SGD training on CPU; momentum tuned per g (paper protocol)."""
    from repro.core import hardware_model as hm
    from repro.core.workload import cnn_classify
    wl = cnn_classify()
    params = wl.init(jax.random.PRNGKey(0))
    ph = hm.PhaseTimes(t_conv_compute_1=1.0, t_fc=0.06, conv_grad_bytes=0.0)
    target, steps, N = 0.55, 500, 16
    base_total = None
    for g in (1, 2, 4, 8, 16):
        t0 = monotonic()
        best = (None, None)
        for mu in (0.0, 0.3, 0.6, 0.9):
            it = _se_iters(wl, params, g, mu, 0.05, steps, target)
            if it is not None and (best[0] is None or it < best[0]):
                best = (it, mu)
        us = (monotonic() - t0) * 1e6
        he = hm.he_time_per_iteration(g, N, ph)
        if best[0] is None:
            _row(f"fig7_g{g}", us, "no-convergence")
            continue
        total = he * best[0]
        if g == 1:
            base_total = total
        _row(f"fig7_g{g}", us,
             f"he={he:.4f};se_iters={best[0]};mu*={best[1]};"
             f"total={total:.2f};speedup_vs_sync="
             f"{(base_total/total if base_total else 1):.2f}")


def fig13_momentum_lesion():
    from repro.core.workload import cnn_classify
    wl = cnn_classify()
    params = wl.init(jax.random.PRNGKey(0))
    g, steps, target = 4, 500, 0.55
    for name, fixed_mu in (("default_0.9", 0.9), ("omnivore_tuned", None)):
        t0 = monotonic()
        if fixed_mu is None:
            cands = [(m, _se_iters(wl, params, g, m, 0.05, steps, target))
                     for m in (0.0, 0.3, 0.6, 0.9)]
            cands = [(m, i) for m, i in cands if i is not None]
            mu, iters = min(cands, key=lambda t: t[1])
        else:
            mu, iters = fixed_mu, _se_iters(wl, params, g, fixed_mu, 0.05,
                                            steps, target)
        us = (monotonic() - t0) * 1e6
        _row(f"fig13_{name}", us, f"mu={mu};iters={iters}")


def fig23_batch_size():
    from repro.core.async_sgd import delayed_sgd_run
    from repro.core.stat_model import iterations_to_loss
    from repro.core.workload import mlp_classify
    target = 0.35
    for b in (4, 16, 64, 256):
        wl = mlp_classify(batch_size=b)
        params = wl.init(jax.random.PRNGKey(0))
        best = None
        t0 = monotonic()
        for eta in (0.2, 0.1, 0.05, 0.02):
            batches = wl.sample_batches(jax.random.PRNGKey(1), 400, b)
            _, losses, _ = delayed_sgd_run(wl.loss_fn, params, batches,
                                           staleness=0, lr=eta, momentum=0.9)
            it = iterations_to_loss(np.asarray(losses), target)
            if it is not None and (best is None or it * b < best[0]):
                best = (it * b, eta, it)
        us = (monotonic() - t0) * 1e6
        d = (f"examples_to_target={best[0]};eta*={best[1]};iters={best[2]}"
             if best else "no-convergence")
        _row(f"fig23_b{b}", us, d)


def fig32_rnn_tradeoff():
    """Paper App. F-F: the compute-group tradeoff on RNN/LSTM models."""
    from repro.core import hardware_model as hm
    from repro.core.workload import rnn_classify
    wl = rnn_classify()
    params = wl.init(jax.random.PRNGKey(0))
    ph = hm.PhaseTimes(t_conv_compute_1=1.0, t_fc=0.08, conv_grad_bytes=0.0)
    target, steps, N = 0.30, 350, 16
    base = None
    for g in (1, 2, 4, 8):
        t0 = monotonic()
        best = (None, None)
        for mu in (0.0, 0.3, 0.6, 0.9):
            it = _se_iters(wl, params, g, mu, 0.1, steps, target)
            if it is not None and (best[0] is None or it < best[0]):
                best = (it, mu)
        us = (monotonic() - t0) * 1e6
        he = hm.he_time_per_iteration(g, N, ph)
        if best[0] is None:
            _row(f"fig32_rnn_g{g}", us, "no-convergence")
            continue
        total = he * best[0]
        if g == 1:
            base = total
        _row(f"fig32_rnn_g{g}", us,
             f"he={he:.4f};se_iters={best[0]};mu*={best[1]};"
             f"total={total:.2f};speedup_vs_sync={(base/total if base else 1):.2f}")


def fig33_schedules():
    """Paper App. F-G: Omnivore's epoch-wise re-tuning vs fixed step decay."""
    from repro.core.auto_optimizer import algorithm1
    from repro.core.async_sgd import delayed_sgd_run
    from repro.core.workload import init_state, make_runner, rnn_classify
    from repro.optim.schedules import step_decay
    wl = rnn_classify()
    runner = make_runner(wl, seed=0)
    state = init_state(wl, seed=0)

    # fixed schedule: eta drops 10x at step 150 (CaffeNet-style)
    t0 = monotonic()
    params = state[0]
    sched = step_decay(0.1, drop=10.0, every=150)
    losses = []
    for phase, steps in ((0, 150), (1, 150)):
        batches = wl.sample_batches(jax.random.PRNGKey(phase + 5), steps,
                                    wl.batch_size)
        params, l, _ = delayed_sgd_run(wl.loss_fn, params, batches,
                                       staleness=0, lr=sched(phase * 150),
                                       momentum=0.9)
        losses.append(np.asarray(l))
    us = (monotonic() - t0) * 1e6
    _row("fig33_default_schedule", us,
         f"final={np.concatenate(losses)[-20:].mean():.4f}")

    t0 = monotonic()
    res = algorithm1(runner, state, n_devices=16, epochs=1, epoch_steps=150,
                     probe_steps=30, g0=4)
    us = (monotonic() - t0) * 1e6
    _row("fig33_omnivore_retune", us,
         f"final={res.losses[-20:].mean():.4f};g={res.g};mu={res.mu};"
         f"eta={res.eta}")


def table_optimizer_vs_bayes():
    from repro.core.auto_optimizer import algorithm1
    from repro.core.bayesian import gp_ei_minimize
    from repro.core.workload import init_state, make_runner, mlp_classify
    wl = mlp_classify()
    runner = make_runner(wl, seed=0)
    state = init_state(wl, seed=0)

    t0 = monotonic()
    res = algorithm1(runner, state, n_devices=16, epochs=1, epoch_steps=150,
                     probe_steps=25, g0=8)
    us1 = (monotonic() - t0) * 1e6
    alg1_loss = float(res.losses[-20:].mean())
    _row("alg1_optimizer", us1,
         f"g={res.g};mu={res.mu};eta={res.eta};loss={alg1_loss:.4f}")

    def objective(eta, mu, g):
        _, losses = runner(state, g=g, mu=mu, eta=eta, steps=150, probe=True)
        arr = np.asarray(losses)
        arr = arr[np.isfinite(arr)]
        return float(arr[-20:].mean()) if arr.size else float("inf")

    t0 = monotonic()
    bres = gp_ei_minimize(objective, etas=(0.1, 0.01, 0.001),
                          mus=(0.0, 0.3, 0.6, 0.9), gs=(1, 2, 4, 8),
                          budget=12, seed=0)
    us2 = (monotonic() - t0) * 1e6
    _row("bayes_optimizer", us2,
         f"evals={bres.evaluations};best={bres.best_y:.4f};"
         f"wall_ratio_vs_alg1={us2/max(us1,1):.1f}x")


def bench_grouped_step():
    """Per-round grouped UPDATE application: closed-form fused single pass
    vs the literal O(g) sequential scan (gradients precomputed, so this
    isolates the optimizer hot path the fused kernel rewrites). Emits
    BENCH_grouped_step.json for cross-PR perf tracking."""
    from repro.core.async_sgd import scan_grouped_update
    from repro.kernels.fused_update.ops import fused_group_update
    from repro.optim.closed_form import grouped_coeffs, head_coeffs
    import functools

    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    params = {"emb": jax.random.normal(ks[0], (2048, 256)),
              "w1": jax.random.normal(ks[1], (512, 1024)),
              "w2": jax.random.normal(ks[2], (1024, 512)),
              "head": jax.random.normal(ks[3], (512, 256))}
    mom = jax.tree.map(jnp.zeros_like, params)
    mask = {k: k == "head" for k in params}
    lr, mu, wd = 0.05, 0.9, 1e-4

    rows = []
    for g in (2, 4, 8):
        grads = jax.tree.map(
            lambda p: jax.random.normal(jax.random.PRNGKey(g), (g,) + p.shape),
            params)
        scan_fn = jax.jit(functools.partial(
            scan_grouped_update, lr=lr, momentum=mu, weight_decay=wd,
            head_mask=mask))
        fused_fn = jax.jit(functools.partial(
            fused_group_update,
            coeffs=grouped_coeffs(g, lr=lr, momentum=mu, weight_decay=wd),
            head_coeffs=head_coeffs(g, lr=lr, momentum=mu, weight_decay=wd),
            head_mask=mask))
        ts = _timeit_interleaved(
            {"scan": lambda: scan_fn(params, grads, mom),
             "fused": lambda: fused_fn(params, grads, mom)},
            warmup=2, iters=11)
        scan_t, fused_t = ts["scan"], ts["fused"]
        speedup = scan_t.min_s / fused_t.min_s
        rows.append({"g": g,
                     "scan": scan_t.row(), "fused": fused_t.row(),
                     "speedup_min": speedup})
        _row(f"grouped_step_g{g}", fused_t.median_s * 1e6,
             f"scan_us={scan_t.median_s * 1e6:.1f};speedup={speedup:.2f}x")

    out = {"bench": "grouped_step", "env": run_metadata(),
           "params": int(sum(p.size for p in jax.tree.leaves(params))),
           "lr": lr, "momentum": mu, "weight_decay": wd,
           "timeit": {"warmup": 2, "iters": 11,
                      "stat": "min+median+iqr; speedups from min"},
           "rows": rows}
    (ROOT / "BENCH_grouped_step.json").write_text(json.dumps(out, indent=2))


def bench_planner():
    """Heterogeneous planner search over a 16-device mixed cluster
    (8xGPU + 8xCPU): full (g, alloc) sweep + discrete-event validation of
    the chosen plan. Emits BENCH_planner.json; the whole search must stay
    under 5 s (it is the inner loop of cluster bring-up)."""
    from repro import cluster

    devices = cluster.parse_cluster_spec(
        "8xgpu-g2.2xlarge,8xcpu-c4.4xlarge")
    cost = cluster.WorkloadCost(flops_per_example=2e9,
                                bytes_per_example=2e8, grad_bytes=4e6)
    batch, t_fc = 64, 0.002

    plan = cluster.best_allocation(devices, global_batch=batch, t_fc=t_fc,
                                   cost=cost, mu_star_total=0.9)
    search_t = _timeit(
        lambda: cluster.best_allocation(devices, global_batch=batch,
                                        t_fc=t_fc, cost=cost,
                                        mu_star_total=0.9),
        warmup=0, iters=3)
    search_s = search_t.median_s

    sim = cluster.simulate_hetero(t_conv=plan.group_times, t_fc=t_fc,
                                  iters=3000, exponential=False)
    err = abs(sim.time_per_iteration - plan.t_iteration) / plan.t_iteration
    _row("planner_search", search_s * 1e6,
         f"g*={plan.g};t_iter={plan.t_iteration*1e3:.3f}ms;"
         f"sim_err={err:.1%};under_5s={search_s < 5.0}")

    rows = []
    for g in (1, 2, 4, 8, 16):
        p = cluster.plan_for_g(devices, g, global_batch=batch, t_fc=t_fc,
                               cost=cost, mu_star_total=0.9)
        rows.append({"g": g, "t_iteration_s": p.t_iteration,
                     "se_penalty": p.se_penalty,
                     "time_score_s": p.time_score,
                     "microbatches": list(p.allocation.microbatches)})
        _row(f"planner_g{g}", p.t_iteration * 1e6,
             f"P_SE={p.se_penalty:.2f};score={p.time_score*1e3:.3f}ms")

    out = {"bench": "planner", "env": run_metadata(),
           "cluster": "8xgpu-g2.2xlarge,8xcpu-c4.4xlarge",
           "global_batch": batch, "t_fc": t_fc,
           "search_s": search_s, "search": search_t.row(),
           "best_g": plan.g,
           "best_microbatches": list(plan.allocation.microbatches),
           "analytic_vs_sim_err": err, "rows": rows}
    (ROOT / "BENCH_planner.json").write_text(json.dumps(out, indent=2))


def _engine_probe(gs=(1, 2, 4, 8)):
    """Child-process half of ``bench_engine``: time the unified engine's
    grouped step per g at whatever device count XLA_FLAGS forced, print one
    JSON line. Run via ``python benchmarks/run.py --engine-probe``.

    With >= 8 devices the probe also runs the overlapped-exchange
    head-to-head: the bucketed SPMD step (``engine.buckets``) vs the
    legacy whole-tree-gather arm (``bucket_bytes=0``), interleaved
    round-robin at g in {2, 4}, with one row per bucket count (the
    ``bucket_bytes`` sweep covers per-leaf / packed / single-slab)."""
    from repro.core.workload import mlp_classify
    from repro.engine import Engine
    from repro.engine.buckets import assign_buckets
    from repro.engine.spmd import (DEFAULT_BUCKET_BYTES, device_batch_split,
                                   make_spmd_grouped_step)
    from repro.launch.mesh import make_group_mesh

    wl = mlp_classify(batch_size=64)
    params = wl.init(jax.random.PRNGKey(0))
    leaves = jax.tree.leaves(params)
    batch = jax.tree.map(lambda x: x[0],
                         wl.sample_batches(jax.random.PRNGKey(1), 1, 64))
    rows = []
    for g in gs:
        eng = Engine(wl.loss_fn, strategy="grouped-fused", num_groups=g,
                     lr=0.05, momentum=0.9, donate=False)
        p, m = params, jax.tree.map(jnp.zeros_like, params)
        for _ in range(12):          # telemetry skips the compile step
            p, m, _ = eng.step(p, m, batch)
        built = next(iter(eng._steps.values()))
        nb = (len(assign_buckets(leaves, [False] * len(leaves),
                                 eng.bucket_bytes))
              if built.mode == "spmd" else 0)
        rows.append({"g": g, "mode": built.mode, "k": built.k,
                     "buckets": nb,
                     "step_us": eng.telemetry.median_step_s() * 1e6,
                     "step": eng.telemetry.stats().row()})

    overlap = []
    if jax.device_count() >= 8:
        mom = jax.tree.map(jnp.zeros_like, params)
        # bucket_bytes sweep: per-leaf buckets (1), small packed buckets
        # (600 B), one slab (default); 0 = whole-tree baseline arm
        sweep = (0, 1, 600, DEFAULT_BUCKET_BYTES)
        for g in (2, 4):
            k = 8 // g
            mesh = make_group_mesh(g, k)
            gb = jax.tree.map(
                lambda t: t.reshape((g, t.shape[0] // g) + t.shape[1:]),
                batch)
            db = device_batch_split(gb, k)
            thunks, nbuckets = {}, {}
            for bb in sweep:
                fn = jax.jit(make_spmd_grouped_step(
                    wl.loss_fn, mesh, lr=0.05, momentum=0.9,
                    bucket_bytes=bb))
                thunks[bb] = (lambda fn=fn: fn(params, mom, db))
                nbuckets[bb] = (len(assign_buckets(
                    leaves, [False] * len(leaves), bb)) if bb > 0 else 0)
            stats = _timeit_interleaved(thunks, warmup=2, iters=15)
            base = stats[0]            # whole-tree arm
            for bb in sweep:
                s = stats[bb]
                overlap.append({
                    "g": g, "k": k, "bucket_bytes": bb,
                    "buckets": nbuckets[bb],
                    "variant": "wholetree" if bb == 0 else "bucketed",
                    "step": s.row(),
                    "speedup_vs_wholetree_min": base.min_s / s.min_s})
    mp_rows = []
    if jax.device_count() >= 8:
        # model-parallel storage head-to-head: same g=2 grouped step with
        # params/momentum stored whole (mp=1) vs mp-sharded over the third
        # mesh axis (mp=2, in-step all-gather + grad slice). The delta is
        # the price of storage sharding on a model that fits either way.
        for mp in (1, 2):
            eng = Engine(wl.loss_fn, strategy="grouped-fused", num_groups=2,
                         mp=mp, lr=0.05, momentum=0.9, donate=False)
            p, m = params, jax.tree.map(jnp.zeros_like, params)
            for _ in range(12):
                p, m, _ = eng.step(p, m, batch)
            built = next(iter(eng._steps.values()))
            mp_rows.append({"g": 2, "mp": mp, "k": built.k,
                            "mode": built.mode,
                            "step_us": eng.telemetry.median_step_s() * 1e6,
                            "step": eng.telemetry.stats().row()})
    print(json.dumps({"device_count": jax.device_count(), "rows": rows,
                      "overlap": overlap, "mp": mp_rows}))


def bench_engine():
    """Unified-engine grouped step: wall time per g on 1 vs 8 forced host
    CPU devices (the SPMD ("group","data") mesh vs the single-device
    path), plus the overlapped bucketed exchange vs whole-tree gather
    head-to-head on the 8-device lane. Emits BENCH_engine.json for
    cross-PR perf tracking (gated by benchmarks/compare.py). Each device
    count needs its own XLA runtime, so the probes run as child
    processes."""
    import subprocess

    results = []
    for n in (1, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env.setdefault("JAX_PLATFORMS", "cpu")
        env["PYTHONPATH"] = str(ROOT / "src") + os.pathsep + \
            env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, str(ROOT / "benchmarks" / "run.py"),
             "--engine-probe"],
            env=env, capture_output=True, text=True, timeout=600)
        if proc.returncode != 0:
            raise RuntimeError(f"engine probe (devices={n}) failed:\n"
                               + proc.stderr[-2000:])
        data = json.loads(proc.stdout.strip().splitlines()[-1])
        results.append(data)
        for row in data["rows"]:
            _row(f"engine_d{data['device_count']}_g{row['g']}",
                 row["step_us"], f"mode={row['mode']};k={row['k']}")
        for row in data.get("overlap", []):
            _row(f"engine_overlap_g{row['g']}_bb{row['bucket_bytes']}",
                 row["step"]["median_us"],
                 f"buckets={row['buckets']};"
                 f"speedup_vs_wholetree="
                 f"{row['speedup_vs_wholetree_min']:.2f}x")
        for row in data.get("mp", []):
            _row(f"engine_mp{row['mp']}_g{row['g']}",
                 row["step"]["median_us"], f"k={row['k']}")

    out = {"bench": "engine", "env": run_metadata(),
           "workload": "mlp_classify(batch=64)",
           "strategy": "grouped-fused",
           "timeit": {"steps": 12, "stat": "min+median+iqr per row "
                                           "('step'); legacy step_us is "
                                           "the median", "skip": 1,
                      "overlap": "interleaved round-robin, warmup=2, "
                                 "iters=15; speedups from min"},
           "device_counts": [r["device_count"] for r in results],
           "runs": results}
    (ROOT / "BENCH_engine.json").write_text(json.dumps(out, indent=2))


def _seed_cnn_loss(params, batch, cfg):
    """The seed repo's caffenet-smoke training formulation, reconstructed:
    generic autodiff through ``lowering_conv_xla`` (pre-custom-VJP, i.e.
    ``lowered_conv_ref``) and the reduce_window max pool. This is the
    "autodiff-through-lowering_conv_xla" train step the PR replaced —
    kept here so the before/after is measured, not remembered."""
    from repro.kernels.lowering_conv.ref import lowered_conv_ref

    x = batch["images"]
    for spec, p in zip(cfg.convs, params["conv"]):
        x = jax.nn.relu(lowered_conv_ref(x, p["w"], stride=spec.stride)
                        + p["b"])
        if spec.pool > 1:
            k = spec.pool
            x = jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                      (1, k, k, 1), (1, k, k, 1), "VALID")
    x = x.reshape(x.shape[0], -1)
    for i, p in enumerate(params["fc"]):
        x = x @ p["w"] + p["b"]
        if i < len(params["fc"]) - 1:
            x = jax.nn.relu(x)
    logp = jax.nn.log_softmax(x, axis=-1)
    return -jnp.take_along_axis(logp, batch["labels"][:, None],
                                axis=-1).mean()


def bench_cnn_throughput(archs=("lenet", "cifarnet", "caffenet"),
                         batch_sizes=(16, 64),
                         impls=("xla", "lowering", "lowering_autodiff",
                                "seed_lowering"),
                         iters: int = 15):
    """CNN images/sec per arch x conv impl x batch size, forward-only and
    full train step (paper §III: the batched-lowering GEMM conv is the
    single-node throughput contribution). Runs the smoke CNN configs
    (CPU-sized but structure-preserving: caffenet-smoke keeps the strided
    big-kernel conv1). The train step is the jitted momentum-SGD step (the
    engine's sync-update semantics; the engine's exec-mode wrappers are
    excluded so the conv path, not the batching mode, is measured).

    impls:
      xla                native conv_general_dilated
      lowering           custom-VJP batched-GEMM backward (this PR)
      lowering_autodiff  generic autodiff through the same lowering, same
                         model code otherwise (same-pool ablation)
      seed_lowering      the seed's whole formulation (autodiff lowering +
                         reduce_window pool) — the before/after headline

    Honest-measurement note (docs/lowering_conv.md): within one jitted
    step XLA CSEs the backward "re-lowering" against the forward's, so
    custom-VJP vs lowering_autodiff is ~parity on CPU; the headline
    speedup vs the seed comes from the custom backward together with the
    pool rewrite this PR ships. Emits BENCH_cnn_throughput.json; speedups
    use min (see _timeit)."""
    import dataclasses

    from repro.data.pipeline import DataConfig, SyntheticImages
    from repro.models import cnn as C
    from repro.optim.sgd import init_momentum

    def make_step(loss_fn):
        @jax.jit
        def step(p, m, bt):
            loss, g = jax.value_and_grad(loss_fn)(p, bt)
            m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
            p = jax.tree.map(lambda pp, mm: pp - 0.05 * mm, p, m)
            return p, m, loss
        return step

    rows = []
    for arch in archs:
        base = C.get_cnn_smoke_config(arch)
        for bsz in batch_sizes:
            data = SyntheticImages(DataConfig(
                batch_size=bsz, image_size=base.image_size,
                channels=base.in_channels, num_classes=base.num_classes,
                seed=0))
            batch = jax.device_put(next(iter(data.batches(1))))
            built = {}
            for impl in impls:
                if impl == "seed_lowering":
                    cfg, lf = base, _seed_cnn_loss
                else:
                    cfg = dataclasses.replace(base, conv_impl=impl)
                    lf = C.loss_fn
                loss_fn = (lambda lf, cfg: lambda p, bt: lf(p, bt, cfg))(
                    lf, cfg)
                params = C.init_params(jax.random.PRNGKey(0), cfg)
                built[impl] = (jax.jit(loss_fn), make_step(loss_fn), params,
                               init_momentum(params))
            thunks = {}
            for impl, (fwd, step, params, mom) in built.items():
                thunks[(impl, "fwd")] = \
                    (lambda fwd, p: lambda: fwd(p, batch))(fwd, params)
                thunks[(impl, "train")] = \
                    (lambda st, p, m: lambda: st(p, m, batch))(step, params,
                                                              mom)
            stats = _timeit_interleaved(thunks, warmup=2, iters=iters)
            for impl in impls:
                fwd_t = stats[(impl, "fwd")]
                train_t = stats[(impl, "train")]
                rows.append({
                    "arch": base.name, "impl": impl, "batch": bsz,
                    "fwd": {**fwd_t.row(),
                            "images_per_s": bsz / fwd_t.min_s},
                    "train": {**train_t.row(),
                              "images_per_s": bsz / train_t.min_s},
                })
                _row(f"cnn_{base.name}_{impl}_b{bsz}",
                     train_t.median_s * 1e6,
                     f"train_img_per_s={bsz / train_t.min_s:.0f};"
                     f"fwd_img_per_s={bsz / fwd_t.min_s:.0f}")

    def _train_min(arch, impl, bsz):
        for r in rows:
            if (r["arch"], r["impl"], r["batch"]) == (arch, impl, bsz):
                return r["train"]["min_us"]
        return None

    summary = {}
    for bsz in batch_sizes:
        cust = _train_min("caffenet-smoke", "lowering", bsz)
        seed = _train_min("caffenet-smoke", "seed_lowering", bsz)
        auto = _train_min("caffenet-smoke", "lowering_autodiff", bsz)
        if cust and seed:
            summary[f"caffenet_smoke_custom_vjp_vs_seed_b{bsz}"] = \
                seed / cust
            _row(f"cnn_speedup_caffenet_b{bsz}", cust,
                 f"custom_vjp_vs_seed={seed / cust:.2f}x;"
                 f"vs_same_pool_autodiff="
                 f"{(auto / cust) if auto else float('nan'):.2f}x")
        if cust and auto:
            summary[f"caffenet_smoke_custom_vjp_vs_autodiff_b{bsz}"] = \
                auto / cust

    out = {"bench": "cnn_throughput", "env": run_metadata(),
           "configs": {a: dataclasses.asdict(C.get_cnn_smoke_config(a))
                       for a in archs},
           "impls": list(impls), "batch_sizes": list(batch_sizes),
           "timeit": {"warmup": 2, "iters": iters,
                      "stat": "min+median+iqr; images/sec and speedups "
                              "from min"},
           "rows": rows, "summary": summary}
    (ROOT / "BENCH_cnn_throughput.json").write_text(json.dumps(out, indent=2))


def roofline_table():
    d = ROOT / "experiments" / "dryrun"
    rows = sorted(d.glob("*__16x16.json"))
    for f in rows:
        r = json.loads(f.read_text())
        if r.get("status") != "ok":
            _row(f"roofline_{r['arch']}_{r['shape']}", 0.0,
                 f"status={r.get('status')}")
            continue
        rf = r["roofline"]
        useful = r.get("useful_flops_frac")
        _row(f"roofline_{r['arch']}_{r['shape']}",
             r.get("compile_s", 0) * 1e6,
             f"bottleneck={rf['bottleneck']};step_ms={rf['step_time']*1e3:.2f};"
             f"tc={rf['t_compute']*1e3:.2f};tm={rf['t_memory']*1e3:.2f};"
             f"tcoll={rf['t_collective']*1e3:.2f};"
             f"useful={round(useful, 3) if useful else None}")


BENCHES = [fig4_lowering_blocksize, fig5_he_model, fig6_implicit_momentum,
           fig7_tradeoff, fig13_momentum_lesion, fig23_batch_size,
           fig32_rnn_tradeoff, fig33_schedules,
           table_optimizer_vs_bayes, bench_grouped_step, bench_planner,
           bench_engine, bench_cnn_throughput, roofline_table]


def main() -> None:
    if "--engine-probe" in sys.argv:
        _engine_probe()
        return
    print("name,us_per_call,derived")
    for bench in BENCHES:
        t0 = monotonic()
        try:
            bench()
        except Exception as e:  # keep the harness running
            _row(bench.__name__, (monotonic() - t0) * 1e6,
                 f"ERROR={type(e).__name__}:{str(e)[:80]}")


if __name__ == "__main__":
    main()
