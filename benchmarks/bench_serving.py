"""Serving benchmark: tok/s + p50/p99 latency vs offered load, continuous
batching vs the static-batch ``serve()`` baseline — emits BENCH_serving.json.

Protocol (same trace for both modes, 8 forced host devices):

- Requests are rid-keyed (``serving.sample_requests``), so the request
  *population* (prompts, generation lengths) is byte-identical at every
  offered rate — only the Poisson arrival times change. Each mode runs the
  trace once unmeasured (absorbs jit compilation; ``ContinuousServer.reset``
  keeps the compiled fns, the static path's prefill/decode are lru-cached),
  then once measured.
- Per-request latencies are summarized as min+median+IQR (``TimeStats.row``)
  so ``benchmarks/compare.py`` gates them with the same IQR-aware rule as
  every other bench, alongside p50/p99 ms and tok/s.
- Goodput gate: the SLO is pinned at 1.5x the measured continuous p99 at
  the LOWEST offered rate (recorded as ``slo_ms``), and the
  ``goodput_gate`` row carries ``{"value": ratio, "floor": 1.3}`` —
  ``compare.py`` fails the fresh emission if continuous batching stops
  sustaining >= 1.3x the static baseline's goodput on the same trace.
  The low-rate lane is the latency-sensitive regime the SLO models:
  spread-out arrivals make the static baseline pay its group-formation
  wait and decode-to-group-max padding, which continuous batching's
  join/leave-every-step slot recycling exists to eliminate. (At
  saturation the whole trace arrives at once and a static batch is
  nearly optimal — gating there would measure arrival bunching, not the
  scheduler.)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

# acceptance lane: 8 forced host CPU devices (set before jax imports)
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + f" {_FLAG}=8").strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.engine import timing  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.obs import spans  # noqa: E402
from repro.obs.meta import run_metadata  # noqa: E402
from repro.obs.metrics import MetricRegistry  # noqa: E402
from repro.serving import (ContinuousServer, poisson_trace,  # noqa: E402
                           sample_requests, static_serve_trace)


def _lat_row(report) -> dict:
    """Per-request latency distribution as a TimeStats row (us) — the
    shape compare.py's IQR-aware gate understands."""
    return timing.stats_of([float(x) for x in report.latencies]).row()


def _mode_row(report, *, mode: str, rate: float, slots: int, page: int,
              slo_s: float) -> dict:
    return {
        "mode": mode, "rate": rate, "slots": slots, "page": page,
        "requests": len(report.rids),
        "latency": _lat_row(report),
        "p50_ms": report.percentile(50) * 1e3,
        "p99_ms": report.percentile(99) * 1e3,
        "queue_wait_p50_ms": float(
            sorted(report.queue_waits)[len(report.queue_waits) // 2]) * 1e3,
        "tok_s": report.throughput,
        "goodput_tok_s": report.goodput(slo_s),
        "makespan_s": report.makespan,
        "occupancy_mean": report.occupancy_mean,
    }


def run_bench(*, arch: str, rates, requests: int, slots: int, page: int,
              seed: int, metrics_out: str = "", trace_out: str = ""):
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    pmax, gmax = 32, 32
    max_seq = -(-(pmax + gmax) // page) * page
    srv = ContinuousServer(cfg, params, slots=slots, page_size=page,
                           max_seq=max_seq, seed=seed)
    gate_rate = min(rates)
    reports = {}
    with spans.maybe_traced(bool(trace_out)) as tracer:
        gate_registry = None
        for rate in rates:
            trace = poisson_trace(rate, requests, seed=seed)
            reqs = sample_requests(trace, cfg, prompt_range=(8, pmax),
                                   gen_range=(4, gmax), seed=seed)
            srv.reset()                      # warmup lane: compile, discard
            srv.run(reqs)
            static_serve_trace(cfg, reqs, batch=slots, params=params)
            reg = MetricRegistry()
            srv.reset(registry=reg)
            cont = srv.run(reqs)
            stat = static_serve_trace(cfg, reqs, batch=slots, params=params,
                                      registry=reg)
            reports[rate] = (cont, stat)
            if rate == gate_rate:
                gate_registry = reg
            print(f"rate={rate:g}: continuous p99="
                  f"{cont.percentile(99) * 1e3:.0f}ms "
                  f"{cont.throughput:.0f} tok/s | static p99="
                  f"{stat.percentile(99) * 1e3:.0f}ms "
                  f"{stat.throughput:.0f} tok/s", flush=True)

    gate_cont, gate_stat = reports[gate_rate]
    slo_s = 1.5 * gate_cont.percentile(99)
    slo_ms = slo_s * 1e3
    rows = []
    for rate in rates:
        cont, stat = reports[rate]
        rows.append(_mode_row(cont, mode="continuous", rate=rate,
                              slots=slots, page=page, slo_s=slo_s))
        rows.append(_mode_row(stat, mode="static", rate=rate, slots=slots,
                              page=page, slo_s=slo_s))
    cg, sg = gate_cont.goodput(slo_s), gate_stat.goodput(slo_s)
    ratio = cg / sg if sg > 0 else 99.0
    # measured_slo_ms deliberately dodges compare.py's "slo_ms" ID key:
    # the SLO here is derived from the run's own p99, so it must describe
    # the row, not identify it (identity must be stable across runs)
    gate = {"name": "goodput_ratio_continuous_vs_static",
            "rate": gate_rate, "measured_slo_ms": slo_ms, "slots": slots,
            "page": page, "continuous_goodput_tok_s": cg,
            "static_goodput_tok_s": sg, "value": ratio, "floor": 1.3}
    print(f"goodput gate @ {slo_ms:.0f}ms SLO (rate {gate_rate:g}): "
          f"continuous {cg:.0f} vs static {sg:.0f} tok/s -> "
          f"ratio {ratio:.2f} (floor 1.3)", flush=True)

    if metrics_out and gate_registry is not None:
        run = run_metadata(extra={"bench": "serving", "arch": cfg.name,
                                  "rate": gate_rate, "slots": slots})
        n = gate_registry.to_jsonl(metrics_out, run)
        print(f"metrics -> {metrics_out} ({n} records)")
    if trace_out:
        from repro.obs import export_chrome_trace
        n = export_chrome_trace(trace_out,
                                tracer=tracer if tracer.enabled else None,
                                metrics=gate_registry)
        print(f"chrome trace -> {trace_out} ({n} events)")

    return {"bench": "serving", "env": run_metadata(),
            "arch": cfg.name, "device_count": jax.device_count(),
            "slots": slots, "page": page, "requests": requests,
            "prompt_range": [8, pmax], "gen_range": [4, gmax],
            "seed": seed, "rates": list(rates), "measured_slo_ms": slo_ms,
            "timeit": {"protocol": "one unmeasured trace run per mode "
                                   "(compile), one measured; latency rows "
                                   "are per-request min+median+iqr",
                       "slo": "1.5x measured continuous p99 at the "
                              "lowest rate"},
            "rows": rows, "goodput_gate": gate}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen2-7b",
                    help="smoke config to serve (default qwen2-7b)")
    ap.add_argument("--smoke", action="store_true",
                    help="single low rate, few requests (CI lane)")
    ap.add_argument("--rates", type=str, default="",
                    help="comma-separated offered loads, req/s")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=Path, default=ROOT / "BENCH_serving.json")
    ap.add_argument("--metrics-out", type=str, default="")
    ap.add_argument("--trace-out", type=str, default="")
    args = ap.parse_args(argv)

    if args.rates:
        rates = tuple(float(r) for r in args.rates.split(","))
    else:
        rates = (10.0,) if args.smoke else (10.0, 20.0, 40.0, 80.0)
    requests = args.requests or (10 if args.smoke else 24)
    slots = min(args.slots, 4) if args.smoke else args.slots

    out = run_bench(arch=args.arch, rates=rates, requests=requests,
                    slots=slots, page=args.page_size, seed=args.seed,
                    metrics_out=args.metrics_out, trace_out=args.trace_out)
    args.out.write_text(json.dumps(out, indent=2))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
