"""Serving benchmark: tok/s + p50/p99 latency vs offered load, continuous
batching vs the static-batch ``serve()`` baseline — emits BENCH_serving.json.

Protocol (same trace for both modes, 8 forced host devices):

- Requests are rid-keyed (``serving.sample_requests``), so the request
  *population* (prompts, generation lengths) is byte-identical at every
  offered rate — only the Poisson arrival times change. Each mode runs the
  trace once unmeasured (absorbs jit compilation; ``ContinuousServer.reset``
  keeps the compiled fns, the static path's prefill/decode are lru-cached),
  then once measured.
- Per-request latencies are summarized as min+median+IQR (``TimeStats.row``)
  so ``benchmarks/compare.py`` gates them with the same IQR-aware rule as
  every other bench, alongside p50/p99 ms and tok/s.
- Goodput gate: the SLO is pinned at 1.5x the measured continuous p99 at
  the LOWEST offered rate (recorded as ``slo_ms``), and the
  ``goodput_gate`` row carries ``{"value": ratio, "floor": 1.3}`` —
  ``compare.py`` fails the fresh emission if continuous batching stops
  sustaining >= 1.3x the static baseline's goodput on the same trace.
  The low-rate lane is the latency-sensitive regime the SLO models:
  spread-out arrivals make the static baseline pay its group-formation
  wait and decode-to-group-max padding, which continuous batching's
  join/leave-every-step slot recycling exists to eliminate. (At
  saturation the whole trace arrives at once and a static batch is
  nearly optimal — gating there would measure arrival bunching, not the
  scheduler.)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

# acceptance lane: 8 forced host CPU devices (set before jax imports)
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = \
        (os.environ.get("XLA_FLAGS", "") + f" {_FLAG}=8").strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_smoke_config  # noqa: E402
from repro.engine import timing  # noqa: E402
from repro.models import transformer as T  # noqa: E402
from repro.obs import spans  # noqa: E402
from repro.obs.meta import run_metadata  # noqa: E402
from repro.obs.metrics import MetricRegistry  # noqa: E402
from repro.serving import (ContinuousServer, PageAllocator,  # noqa: E402
                           PagedCacheSpec, init_pages, paged_decode_step,
                           poisson_trace, sample_requests,
                           static_serve_trace)


def _lat_row(report) -> dict:
    """Per-request latency distribution as a TimeStats row (us) — the
    shape compare.py's IQR-aware gate understands."""
    return timing.stats_of([float(x) for x in report.latencies]).row()


def _mode_row(report, *, mode: str, rate: float, slots: int, page: int,
              slo_s: float) -> dict:
    return {
        "mode": mode, "rate": rate, "slots": slots, "page": page,
        "requests": len(report.rids),
        "latency": _lat_row(report),
        "p50_ms": report.percentile(50) * 1e3,
        "p99_ms": report.percentile(99) * 1e3,
        "queue_wait_p50_ms": float(
            sorted(report.queue_waits)[len(report.queue_waits) // 2]) * 1e3,
        "tok_s": report.throughput,
        "goodput_tok_s": report.goodput(slo_s),
        "makespan_s": report.makespan,
        "occupancy_mean": report.occupancy_mean,
    }


def bench_decode_steps(cfg, params, *, slots: int, page_list, seed: int,
                       capacity: int = 256, short: int = 64,
                       iters: int = 50):
    """Per-token decode-step cost, attention isolated by arm comparison.

    Every arm times the SAME full decode step (stack, writes, unembed) on
    the same random pools; only the attention gather width varies, so the
    deltas are attention bandwidth:

    - ``full@capacity``: full-width dense gather, row at position W-1 —
      the old hot path at its design point.
    - ``full@short``: full-width gather with only ``short`` live tokens —
      what every request paid before the bucket ladder, regardless of
      live context.
    - ``bucket@short``: the gather narrowed to the live page bucket — the
      served cost on a pool provisioned at ``capacity/short``x the live
      context.

    Emits the ``short_context_decode_speedup`` floor gate (full@short /
    bucket@short, floor 1.5) per page size, and a ``paged_kernel_parity``
    floor gate: the in-kernel Pallas walk (interpret mode on CPU) must
    match the dense-gather logits — the correctness lane CI runs on every
    push, so a kernel regression fails the perf gate, not just tests.
    """
    rows, gates = [], []
    rng = np.random.default_rng(seed)
    for page in page_list:
        spec = PagedCacheSpec.for_config(cfg, num_slots=slots,
                                         page_size=page, max_seq=capacity)
        alloc = PageAllocator(spec)
        for s in range(slots):
            alloc.ensure(s, capacity)
        table = jnp.asarray(alloc.tables)
        pools = {k: jnp.asarray(rng.standard_normal(v.shape), v.dtype)
                 for k, v in init_pages(spec).items()}
        tok = jnp.asarray(rng.integers(cfg.vocab_size, size=(slots, 1)),
                          jnp.int32)
        active = jnp.ones((slots,), bool)

        def step(gp, ctx, impl="xla"):
            pos = jnp.full((slots,), ctx - 1, jnp.int32)

            @jax.jit
            def f(pools, tok):
                logits, _ = paged_decode_step(
                    params, pools, table, tok, pos, active, cfg,
                    window=None, attn_impl=impl, gather_pages=gp)
                return logits
            return lambda: f(pools, tok)

        arms = [("full@capacity", None, capacity),
                ("full@short", None, short),
                ("bucket@short", short // page, short)]
        stats = {}
        for variant, gp, ctx in arms:
            st = timing.probe(step(gp, ctx), warmup=3, iters=iters)
            stats[variant] = st
            rows.append({"name": "decode_step", "impl": "xla",
                         "page": page, "slots": slots, "variant": variant,
                         "context": ctx,
                         "gathered_pages": (gp if gp is not None
                                            else spec.pages_per_slot),
                         "step": st.row()})
            print(f"decode_step page={page} {variant}: "
                  f"{st.min_s * 1e6:.0f}us/step", flush=True)
        speedup = stats["full@short"].min_s / stats["bucket@short"].min_s
        gates.append({"name": "short_context_decode_speedup", "page": page,
                      "slots": slots, "context": short,
                      "value": speedup, "floor": 1.5})
        print(f"decode_step page={page}: short-context speedup "
              f"{speedup:.2f}x (floor 1.5)", flush=True)

        # parity at the largest page = fewest interpret-mode grid steps
        if page == max(page_list):
            lx = np.asarray(step(None, short)(), np.float32)
            lp = np.asarray(step(None, short, impl="pallas")(), np.float32)
            diff = float(np.abs(lx - lp).max())
            # tolerance scales with logit magnitude: smoke configs decode
            # in bf16 (~0.8% eps), so parity is relative, not absolute
            tol = 3e-2 * max(1.0, float(np.abs(lx).max()))
            ok = diff <= tol
            gates.append({"name": "paged_kernel_parity", "impl": "pallas",
                          "page": page, "slots": slots,
                          "max_abs_diff": diff,
                          "value": 1.0 if ok else 0.0, "floor": 1.0})
            print(f"paged kernel parity (interpret, page={page}): "
                  f"max|d|={diff:.3e} -> {'ok' if ok else 'FAIL'}",
                  flush=True)
    return {"slots": slots, "capacity": capacity, "short_context": short,
            "page_list": list(page_list), "rows": rows, "gates": gates}


def run_bench(*, arch: str, rates, requests: int, slots: int, page: int,
              seed: int, decode_pages=(8, 16, 32), decode_iters: int = 50,
              metrics_out: str = "", trace_out: str = ""):
    cfg = get_smoke_config(arch)
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    decode = bench_decode_steps(cfg, params, slots=slots,
                                page_list=decode_pages, seed=seed,
                                iters=decode_iters)
    pmax, gmax = 32, 32
    max_seq = -(-(pmax + gmax) // page) * page
    srv = ContinuousServer(cfg, params, slots=slots, page_size=page,
                           max_seq=max_seq, seed=seed)
    # compile the whole decode gather ladder + prefill buckets up front:
    # which bucket a step needs depends on wall-clock admission order, so
    # an unmeasured trace run alone cannot guarantee compile coverage
    srv.warmup(range(1, pmax + 1))
    gate_rate = min(rates)
    reports = {}
    with spans.maybe_traced(bool(trace_out)) as tracer:
        gate_registry = None
        for rate in rates:
            trace = poisson_trace(rate, requests, seed=seed)
            reqs = sample_requests(trace, cfg, prompt_range=(8, pmax),
                                   gen_range=(4, gmax), seed=seed)
            srv.reset()                      # warmup lane: compile, discard
            srv.run(reqs)
            static_serve_trace(cfg, reqs, batch=slots, params=params)
            reg = MetricRegistry()
            srv.reset(registry=reg)
            cont = srv.run(reqs)
            stat = static_serve_trace(cfg, reqs, batch=slots, params=params,
                                      registry=reg)
            reports[rate] = (cont, stat)
            if rate == gate_rate:
                gate_registry = reg
            print(f"rate={rate:g}: continuous p99="
                  f"{cont.percentile(99) * 1e3:.0f}ms "
                  f"{cont.throughput:.0f} tok/s | static p99="
                  f"{stat.percentile(99) * 1e3:.0f}ms "
                  f"{stat.throughput:.0f} tok/s", flush=True)

    gate_cont, gate_stat = reports[gate_rate]
    slo_s = 1.5 * gate_cont.percentile(99)
    slo_ms = slo_s * 1e3
    rows = []
    for rate in rates:
        cont, stat = reports[rate]
        rows.append(_mode_row(cont, mode="continuous", rate=rate,
                              slots=slots, page=page, slo_s=slo_s))
        rows.append(_mode_row(stat, mode="static", rate=rate, slots=slots,
                              page=page, slo_s=slo_s))
    cg, sg = gate_cont.goodput(slo_s), gate_stat.goodput(slo_s)
    ratio = cg / sg if sg > 0 else 99.0
    # measured_slo_ms deliberately dodges compare.py's "slo_ms" ID key:
    # the SLO here is derived from the run's own p99, so it must describe
    # the row, not identify it (identity must be stable across runs)
    gate = {"name": "goodput_ratio_continuous_vs_static",
            "rate": gate_rate, "measured_slo_ms": slo_ms, "slots": slots,
            "page": page, "continuous_goodput_tok_s": cg,
            "static_goodput_tok_s": sg, "value": ratio, "floor": 1.3}
    print(f"goodput gate @ {slo_ms:.0f}ms SLO (rate {gate_rate:g}): "
          f"continuous {cg:.0f} vs static {sg:.0f} tok/s -> "
          f"ratio {ratio:.2f} (floor 1.3)", flush=True)

    if metrics_out and gate_registry is not None:
        run = run_metadata(extra={"bench": "serving", "arch": cfg.name,
                                  "rate": gate_rate, "slots": slots})
        n = gate_registry.to_jsonl(metrics_out, run)
        print(f"metrics -> {metrics_out} ({n} records)")
    if trace_out:
        from repro.obs import export_chrome_trace
        n = export_chrome_trace(trace_out,
                                tracer=tracer if tracer.enabled else None,
                                metrics=gate_registry)
        print(f"chrome trace -> {trace_out} ({n} events)")

    return {"bench": "serving", "env": run_metadata(),
            "arch": cfg.name, "device_count": jax.device_count(),
            "slots": slots, "page": page, "requests": requests,
            "prompt_range": [8, pmax], "gen_range": [4, gmax],
            "seed": seed, "rates": list(rates), "measured_slo_ms": slo_ms,
            "timeit": {"protocol": "one unmeasured trace run per mode "
                                   "(compile), one measured; latency rows "
                                   "are per-request min+median+iqr",
                       "slo": "1.5x measured continuous p99 at the "
                              "lowest rate"},
            "rows": rows, "goodput_gate": gate, "decode_step": decode}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--arch", default="qwen2-7b",
                    help="smoke config to serve (default qwen2-7b)")
    ap.add_argument("--smoke", action="store_true",
                    help="single low rate, few requests (CI lane)")
    ap.add_argument("--rates", type=str, default="",
                    help="comma-separated offered loads, req/s")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", type=Path, default=ROOT / "BENCH_serving.json")
    ap.add_argument("--metrics-out", type=str, default="")
    ap.add_argument("--trace-out", type=str, default="")
    args = ap.parse_args(argv)

    if args.rates:
        rates = tuple(float(r) for r in args.rates.split(","))
    else:
        rates = (10.0,) if args.smoke else (10.0, 20.0, 40.0, 80.0)
    requests = args.requests or (10 if args.smoke else 24)
    slots = min(args.slots, 4) if args.smoke else args.slots

    out = run_bench(arch=args.arch, rates=rates, requests=requests,
                    slots=slots, page=args.page_size, seed=args.seed,
                    decode_pages=(16,) if args.smoke else (8, 16, 32),
                    decode_iters=10 if args.smoke else 50,
                    metrics_out=args.metrics_out, trace_out=args.trace_out)
    args.out.write_text(json.dumps(out, indent=2))
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
