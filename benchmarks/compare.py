"""Perf gate: compare fresh BENCH_*.json emissions against committed
baselines and fail on regression — the four bench archives become an
enforced perf trajectory instead of a passive record.

Metric model: every ``TimeStats.row()`` dict (``{"min_us", "median_us",
"iqr_us", "iters"}``) anywhere inside a bench JSON is one metric, named by
its path; list entries are identified by their stable keys (g, arch,
impl, batch, ...) rather than position, so reordering rows does not
invent regressions.

Gate rule (IQR-aware, per metric)::

    fresh_min_us > base_min_us * (1 + tol) + max(base_iqr, fresh_iqr)

``min_us`` is the noise-robust point estimate (see
``engine.timing.TimeStats``); the IQR term widens the tolerance exactly
where the measurement itself certifies spread, so a noisy shared-CPU box
does not produce false alarms while a clean 2x regression on a quiet
metric still trips the default 15%% threshold.

Cross-machine mode (``--normalize``): CI compares baselines committed
from one machine against fresh numbers from another. The median of
per-metric ratios (fresh/base) over ALL shared metrics estimates the
machine-speed factor, and each metric is judged on its ratio relative to
that median. Blind spot (documented, accepted): a uniform slowdown of
every metric reads as "slower machine" — the gate catches *relative*
regressions, which is what a code change produces.

Floor gates: any ``{"value": v, "floor": f}`` dict in a bench JSON is a
quality metric gated as ``v >= f`` against the floor embedded in the
*fresh* emission (the floor travels with the code, so raising it is an
explicit change, never a baseline drift). Used by BENCH_serving.json's
continuous-vs-static goodput ratio. Floor metrics present in the
baseline but absent from fresh fail, like vanished timing metrics.

Environment guard: every BENCH emitter stamps ``run_metadata()`` under
``"env"`` (``repro.obs.meta``). Under ``--normalize`` the gate REFUSES
to compare files whose strict env keys (jax version, backend, device
kind/count) differ — a different device pool is a different benchmark,
not a machine-speed factor. Files without a stamp (pre-observability
baselines) compare as before; ``--allow-env-mismatch`` overrides.

Exit status: 0 = pass, 1 = regression (or a baseline metric disappeared,
which would otherwise silently shrink coverage, or an env-mismatch
refusal), 2 = usage error.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.obs.meta import env_mismatches  # noqa: E402

#: keys that identify a row inside a list (checked in order); values must
#: be scalars. "bench"/"device_count" identify top-level sections.
ID_KEYS = ("bench", "device_count", "g", "mp", "arch", "impl", "batch",
           "bucket_bytes", "buckets", "mode", "name", "variant",
           "rate", "slo_ms", "slots", "page")

STATS_KEYS = {"min_us", "median_us", "iqr_us"}
#: a dict carrying both keys is a floor-gated quality metric: the fresh
#: emission must satisfy value >= floor (e.g. the serving goodput ratio).
FLOOR_KEYS = {"value", "floor"}


def _ident(d: dict) -> str:
    parts = [f"{k}={d[k]}" for k in ID_KEYS
             if k in d and not isinstance(d[k], (dict, list))]
    return ",".join(parts)


def _extract(node, match, prefix: str = "") -> dict:
    """{metric_name: row} for every dict in the document satisfying
    ``match`` (a set of keys the row must carry)."""
    out = {}
    if isinstance(node, dict):
        if match <= set(node):
            # an identified matching row names itself — several floor
            # gates sharing one list must not collapse onto one metric
            ident = _ident(node)
            out[f"{prefix}[{ident}]" if ident else (prefix or "root")] = node
            return out
        ident = _ident(node)
        base = f"{prefix}[{ident}]" if ident else prefix
        for key, val in node.items():
            if isinstance(val, (dict, list)):
                out.update(_extract(
                    val, match, f"{base}.{key}" if base else key))
    elif isinstance(node, list):
        for i, val in enumerate(node):
            if isinstance(val, dict):
                # identified rows name themselves (dict branch); only
                # anonymous rows fall back to their (unstable) position
                tag = "" if _ident(val) else f"[{i}]"
                out.update(_extract(val, match, f"{prefix}{tag}"))
            elif isinstance(val, list):
                out.update(_extract(val, match, f"{prefix}[{i}]"))
    return out


def extract_metrics(node, prefix: str = "") -> dict:
    """{metric_name: stats_row} for every TimeStats row in the document."""
    return _extract(node, STATS_KEYS, prefix)


def extract_floors(node, prefix: str = "") -> dict:
    """{metric_name: floor_row} for every ``{"value", "floor"}`` quality
    gate in the document."""
    return _extract(node, FLOOR_KEYS, prefix)


def load_bench(path: Path) -> dict:
    return extract_metrics(json.loads(path.read_text()))


def check_floors(base: dict, fresh: dict) -> dict:
    """Gate every floor metric in the fresh emission against its own
    embedded floor (the floor travels with the emission, so raising it is
    an explicit code change). Baseline floor metrics absent from fresh
    are failures — gate coverage must not silently shrink."""
    rows, failures = [], 0
    for m in sorted(fresh):
        value, floor = fresh[m]["value"], fresh[m]["floor"]
        ok = value >= floor
        failures += 0 if ok else 1
        rows.append({"metric": m, "value": value, "floor": floor,
                     "base_value": base[m]["value"] if m in base else None,
                     "status": "ok" if ok else "below-floor"})
    return {"rows": rows, "failures": failures,
            "missing": sorted(set(base) - set(fresh))}


def compare_metrics(base: dict, fresh: dict, *, tol: float = 0.15,
                    normalize: bool = False) -> dict:
    """Compare shared metrics; returns a report dict (see keys below).

    ``rows``: per-metric dicts with base/fresh min_us, ratio, the
    IQR-aware threshold, and status in {"ok", "regression", "improved",
    "new"}. ``missing``: baseline metrics absent from fresh (a failure —
    coverage must not silently shrink). ``speed``: the machine-speed
    normalization factor applied (1.0 unless ``normalize``).
    """
    shared = sorted(set(base) & set(fresh))
    missing = sorted(set(base) - set(fresh))
    new = sorted(set(fresh) - set(base))

    speed = 1.0
    if normalize and shared:
        ratios = sorted(fresh[m]["min_us"] / base[m]["min_us"]
                        for m in shared if base[m]["min_us"] > 0)
        if ratios:
            speed = ratios[len(ratios) // 2]

    rows, regressions = [], 0
    for m in shared:
        b, f = base[m], fresh[m]
        fresh_min = f["min_us"] / speed
        iqr = max(b.get("iqr_us", 0.0), f.get("iqr_us", 0.0) / speed)
        threshold = b["min_us"] * (1.0 + tol) + iqr
        ratio = fresh_min / b["min_us"] if b["min_us"] > 0 else float("inf")
        if fresh_min > threshold:
            status = "regression"
            regressions += 1
        elif ratio < 1.0 / (1.0 + tol):
            status = "improved"
        else:
            status = "ok"
        rows.append({"metric": m, "base_min_us": b["min_us"],
                     "fresh_min_us": f["min_us"],
                     "normalized_min_us": fresh_min,
                     "ratio": ratio, "threshold_us": threshold,
                     "iqr_slack_us": iqr, "status": status})
    for m in new:
        rows.append({"metric": m, "base_min_us": None,
                     "fresh_min_us": fresh[m]["min_us"],
                     "normalized_min_us": fresh[m]["min_us"] / speed,
                     "ratio": None, "threshold_us": None,
                     "iqr_slack_us": None, "status": "new"})
    return {"rows": rows, "missing": missing, "speed": speed,
            "regressions": regressions, "shared": len(shared)}


def markdown_table(name: str, report: dict, *, show_ok: bool = True) -> str:
    lines = [f"### {name}",
             "",
             f"machine-speed factor: {report['speed']:.3f} | "
             f"shared metrics: {report['shared']} | "
             f"regressions: {report['regressions']}",
             "",
             "| metric | base min (us) | fresh min (us) | delta | status |",
             "|---|---:|---:|---:|---|"]
    for r in report["rows"]:
        if not show_ok and r["status"] == "ok":
            continue
        delta = (f"{(r['ratio'] - 1) * 100:+.1f}%" if r["ratio"] is not None
                 else "—")
        base = (f"{r['base_min_us']:.1f}" if r["base_min_us"] is not None
                else "—")
        mark = {"regression": "**REGRESSION**", "improved": "improved",
                "ok": "ok", "new": "new"}[r["status"]]
        lines.append(f"| `{r['metric']}` | {base} | "
                     f"{r['fresh_min_us']:.1f} | {delta} | {mark} |")
    for m in report["missing"]:
        lines.append(f"| `{m}` | — | — | — | **MISSING** |")
    floors = report.get("floors")
    if floors and (floors["rows"] or floors["missing"]):
        lines += ["", "| quality gate | floor | value | status |",
                  "|---|---:|---:|---|"]
        for r in floors["rows"]:
            mark = "ok" if r["status"] == "ok" else "**BELOW FLOOR**"
            lines.append(f"| `{r['metric']}` | {r['floor']:.2f} | "
                         f"{r['value']:.2f} | {mark} |")
        for m in floors["missing"]:
            lines.append(f"| `{m}` | — | — | **MISSING** |")
    lines.append("")
    return "\n".join(lines)


def _env_of(path: Path):
    env = json.loads(path.read_text()).get("env")
    return env if isinstance(env, dict) else None


def compare_dirs(base_dir: Path, fresh_dir: Path, *, tol: float,
                 normalize: bool, benches=None,
                 allow_env_mismatch: bool = False):
    """Compare every BENCH_*.json present in ``base_dir`` against its twin
    in ``fresh_dir``. Returns (ok, per-file reports, markdown)."""
    files = sorted(base_dir.glob("BENCH_*.json"))
    if benches:
        want = {f"BENCH_{b}.json" for b in benches}
        files = [f for f in files if f.name in want]
    if not files:
        raise FileNotFoundError(f"no BENCH_*.json baselines in {base_dir}")
    ok, reports, md = True, {}, []
    for f in files:
        twin = fresh_dir / f.name
        if not twin.exists():
            ok = False
            reports[f.name] = {"error": "fresh file missing"}
            md.append(f"### {f.name}\n\n**MISSING fresh emission** — the "
                      "bench did not run or crashed.\n")
            continue
        if normalize and not allow_env_mismatch:
            mism = env_mismatches(_env_of(f), _env_of(twin))
            if mism:
                ok = False
                reports[f.name] = {
                    "error": "env mismatch: " + "; ".join(mism)}
                md.append(
                    f"### {f.name}\n\n**ENV MISMATCH** — --normalize "
                    "refuses to absorb a structurally different "
                    "environment into the machine-speed factor:\n\n"
                    + "".join(f"- {m}\n" for m in mism)
                    + "\n(re-baseline, or pass --allow-env-mismatch to "
                      "override)\n")
                continue
        base_doc = json.loads(f.read_text())
        twin_doc = json.loads(twin.read_text())
        rep = compare_metrics(extract_metrics(base_doc),
                              extract_metrics(twin_doc), tol=tol,
                              normalize=normalize)
        rep["floors"] = check_floors(extract_floors(base_doc),
                                     extract_floors(twin_doc))
        reports[f.name] = rep
        md.append(markdown_table(f.name, rep))
        if (rep["regressions"] or rep["missing"]
                or rep["floors"]["failures"] or rep["floors"]["missing"]):
            ok = False
    return ok, reports, "\n".join(md)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", type=Path,
                    help="directory holding the committed BENCH_*.json")
    ap.add_argument("fresh", type=Path,
                    help="directory holding the freshly emitted BENCH_*.json")
    ap.add_argument("--tol", type=float, default=0.15,
                    help="relative min_us regression tolerance "
                         "(default 0.15; IQR slack is added on top)")
    ap.add_argument("--normalize", action="store_true",
                    help="divide fresh timings by the median fresh/base "
                         "ratio (cross-machine CI mode); refuses "
                         "strict-env mismatches (module docstring)")
    ap.add_argument("--allow-env-mismatch", action="store_true",
                    help="compare despite differing env stamps (e.g. a "
                         "deliberate jax upgrade before re-baselining)")
    ap.add_argument("--benches", type=str, default="",
                    help="comma-separated bench names (default: every "
                         "baseline file)")
    ap.add_argument("--markdown", type=Path, default=None,
                    help="write the per-bench delta table here "
                         "(e.g. $GITHUB_STEP_SUMMARY)")
    args = ap.parse_args(argv)

    try:
        ok, reports, md = compare_dirs(
            args.baseline, args.fresh, tol=args.tol,
            normalize=args.normalize,
            benches=[b for b in args.benches.split(",") if b],
            allow_env_mismatch=args.allow_env_mismatch)
    except FileNotFoundError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2

    print(md)
    if args.markdown:
        with open(args.markdown, "a") as fh:
            fh.write(md + "\n")
    for name, rep in reports.items():
        fl = rep.get("floors", {"failures": 0, "missing": [], "rows": []})
        if "error" in rep:
            print(f"FAIL {name}: {rep['error']}")
        elif (rep["regressions"] or rep["missing"] or fl["failures"]
                or fl["missing"]):
            print(f"FAIL {name}: {rep['regressions']} regression(s), "
                  f"{len(rep['missing'])} missing metric(s), "
                  f"{fl['failures']} below-floor, "
                  f"{len(fl['missing'])} missing floor gate(s)")
        else:
            extra = (f" + {len(fl['rows'])} floor gate(s)"
                     if fl["rows"] else "")
            print(f"PASS {name}: {rep['shared']} metrics within "
                  f"tolerance{extra}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
