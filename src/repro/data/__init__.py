from repro.data import pipeline

__all__ = ["pipeline"]
