"""Data pipeline: deterministic synthetic corpora (token LM + image
classification) behind the same iterator interface a file-backed loader
would use, with per-host sharding, packing, and prefetch.

The paper's datasets (ImageNet/CIFAR/MNIST, Fig. 8) are not shippable in
this container; ``synthetic_lm`` / ``synthetic_images`` generate workloads
with the same shapes and a learnable signal (so statistical-efficiency
experiments have a real convergence target — see core.workload for the
small variants used by the optimizer experiments).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int
    seq_len: int = 0               # LM
    image_size: int = 0            # vision
    channels: int = 3
    vocab_size: int = 0
    num_classes: int = 0
    seed: int = 0
    host_index: int = 0            # per-host sharding
    host_count: int = 1


class SyntheticLM:
    """Markov-chain token stream: next token depends on the current one, so
    a model can actually reduce loss below uniform entropy."""

    def __init__(self, cfg: DataConfig, order_temp: float = 2.0):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        logits = rng.normal(size=(min(v, 512), min(v, 512))) * order_temp
        self._trans = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
        self._v_eff = min(v, 512)

    def batches(self, steps: int) -> Iterator[dict]:
        cfg = self.cfg
        local = cfg.batch_size // cfg.host_count
        rng = np.random.default_rng(
            (cfg.seed, cfg.host_index, 1))
        for _ in range(steps):
            toks = np.empty((local, cfg.seq_len + 1), dtype=np.int32)
            toks[:, 0] = rng.integers(self._v_eff, size=local)
            for t in range(cfg.seq_len):
                p = self._trans[toks[:, t]]
                c = p.cumsum(axis=-1)
                u = rng.random((local, 1))
                toks[:, t + 1] = (u > c).sum(axis=-1)
            yield {"tokens": jnp.asarray(toks[:, :-1]),
                   "labels": jnp.asarray(toks[:, 1:])}


class SyntheticImages:
    """Class-prototype images + noise (paper's CNN workloads shape)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self._protos = rng.normal(size=(cfg.num_classes, cfg.image_size,
                                        cfg.image_size, cfg.channels))

    def batches(self, steps: int) -> Iterator[dict]:
        cfg = self.cfg
        local = cfg.batch_size // cfg.host_count
        rng = np.random.default_rng((cfg.seed, cfg.host_index, 2))
        for _ in range(steps):
            y = rng.integers(cfg.num_classes, size=local)
            x = self._protos[y] + 0.5 * rng.normal(
                size=(local, cfg.image_size, cfg.image_size, cfg.channels))
            yield {"images": jnp.asarray(x, jnp.float32),
                   "labels": jnp.asarray(y, jnp.int32)}


def prefetch(it: Iterator[dict], depth: int = 2) -> Iterator[dict]:
    """Simple software pipeline (device put ahead of consumption)."""
    import collections
    buf = collections.deque()
    for batch in it:
        buf.append(jax.device_put(batch))
        if len(buf) > depth:
            yield buf.popleft()
    while buf:
        yield buf.popleft()
