"""Data pipeline: deterministic synthetic corpora (token LM + image
classification) behind the same iterator interface a file-backed loader
would use, with per-host sharding, packing, and prefetch.

The paper's datasets (ImageNet/CIFAR/MNIST, Fig. 8) are not shippable in
this container; ``synthetic_lm`` / ``synthetic_images`` generate workloads
with the same shapes and a learnable signal (so statistical-efficiency
experiments have a real convergence target — see core.workload for the
small variants used by the optimizer experiments).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Iterator

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch_size: int
    seq_len: int = 0               # LM
    image_size: int = 0            # vision
    channels: int = 3
    vocab_size: int = 0
    num_classes: int = 0
    seed: int = 0
    host_index: int = 0            # per-host sharding
    host_count: int = 1


class SyntheticLM:
    """Markov-chain token stream: next token depends on the current one, so
    a model can actually reduce loss below uniform entropy.

    Sampling is the inverse-CDF over cumulative transition rows,
    precomputed once: row v of the cumulative matrix is offset by +v, so
    the flattened array is globally sorted and one vectorized
    ``searchsorted`` per timestep samples the whole batch (the old path
    re-did a (local, V) gather + cumsum + compare-sum per timestep in
    Python, which dominated small-step runs). Draws the same uniforms in
    the same order as the old loop, so token streams are unchanged.
    """

    def __init__(self, cfg: DataConfig, order_temp: float = 2.0):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        logits = rng.normal(size=(min(v, 512), min(v, 512))) * order_temp
        self._trans = np.exp(logits) / np.exp(logits).sum(-1, keepdims=True)
        self._v_eff = min(v, 512)
        cum = np.cumsum(self._trans, axis=-1)
        cum[:, -1] = 1.0          # exact top: u in [0,1) can never overflow
        self._cum_flat = (cum + np.arange(self._v_eff)[:, None]).ravel()

    def batches(self, steps: int) -> Iterator[dict]:
        """Yields HOST numpy batches — ``prefetch`` owns the single
        host->device transfer (see its docstring)."""
        cfg = self.cfg
        local = cfg.batch_size // cfg.host_count
        v = self._v_eff
        rng = np.random.default_rng(
            (cfg.seed, cfg.host_index, 1))
        for _ in range(steps):
            toks = np.empty((local, cfg.seq_len + 1), dtype=np.int32)
            toks[:, 0] = rng.integers(v, size=local)
            for t in range(cfg.seq_len):
                cur = toks[:, t]
                u = rng.random(local)
                nxt = np.searchsorted(self._cum_flat, cur + u) - cur * v
                # clip both ends: u == 0.0 exactly lands on the previous
                # row's terminal 1.0 (-> -1); float roundoff near 1 could
                # land past the row (-> v)
                toks[:, t + 1] = np.clip(nxt, 0, v - 1)
            yield {"tokens": np.ascontiguousarray(toks[:, :-1]),
                   "labels": np.ascontiguousarray(toks[:, 1:])}


class SyntheticImages:
    """Class-prototype images + noise (paper's CNN workloads shape)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self._protos = rng.normal(size=(cfg.num_classes, cfg.image_size,
                                        cfg.image_size, cfg.channels))

    def batches(self, steps: int) -> Iterator[dict]:
        """Yields HOST numpy batches (transfer belongs to ``prefetch``)."""
        cfg = self.cfg
        local = cfg.batch_size // cfg.host_count
        rng = np.random.default_rng((cfg.seed, cfg.host_index, 2))
        for _ in range(steps):
            y = rng.integers(cfg.num_classes, size=local)
            x = self._protos[y] + 0.5 * rng.normal(
                size=(local, cfg.image_size, cfg.image_size, cfg.channels))
            yield {"images": x.astype(np.float32),
                   "labels": y.astype(np.int32)}


def prefetch(it: Iterator[dict], depth: int = 2, tracer=None,
             metrics=None) -> Iterator[dict]:
    """Software pipeline that owns the host->device transfer.

    Contract (pinned by tests/test_hlo_and_substrate.py::
    test_pipeline_host_to_device_contract): generators yield HOST
    numpy batches and ``prefetch`` performs the one ``jax.device_put``,
    ``depth`` batches ahead of consumption — so the transfer of batch
    i+depth is in flight while the consumer computes on batch i. (The old
    generators yielded ``jnp`` arrays, which made the ``device_put`` here
    a no-op and the "prefetch" a plain buffer.)

    ``tracer`` (an ``obs.spans`` tracer; defaults to the installed one)
    wraps each transfer in a ``data.h2d`` span; ``metrics`` (an
    ``obs.metrics.MetricRegistry``) records the transfer-dispatch wall
    time into an ``h2d_s`` series. Both are free when disabled: the
    transfer is only timed when someone is listening.
    """
    import collections

    from repro.obs import spans
    if tracer is None:
        tracer = spans.current()
    h2d = metrics.series("h2d_s") if metrics is not None else None
    timed = h2d is not None or tracer.enabled
    buf = collections.deque()
    for i, batch in enumerate(it):
        with tracer.span("data.h2d", index=i) as sp:
            if timed:
                t0 = time.perf_counter()
                dev = jax.device_put(batch)
                dt = time.perf_counter() - t0
                sp.set(dispatch_s=dt)
                if h2d is not None:
                    h2d.append(dt, step=i)
            else:
                dev = jax.device_put(batch)
        buf.append(dev)
        if len(buf) > depth:
            yield buf.popleft()
    while buf:
        yield buf.popleft()
