"""Griffin / RecurrentGemma recurrent block: gated temporal conv + RG-LRU.
[arXiv:2402.19427]

RG-LRU:  a_t = exp(-c * softplus(Lambda) * sigmoid(W_a x_t))
         h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(W_x x_t) * x_t)

Training/prefill evaluates the linear recurrence with an associative scan
(log-depth on TPU); decode is the O(1) step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

RG_C = 8.0


def _d_rnn(cfg: ArchConfig) -> int:
    return cfg.hybrid.d_rnn or cfg.d_model


def init_rglru_block(key, cfg: ArchConfig):
    d = cfg.d_model
    dr = _d_rnn(cfg)
    k = cfg.hybrid.conv_width
    ks = jax.random.split(key, 6)
    dt = cfg.dtype("param")
    return {
        "w_gate_branch": (jax.random.normal(ks[0], (d, dr)) * d ** -0.5).astype(dt),
        "w_rec_in": (jax.random.normal(ks[1], (d, dr)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[2], (k, dr)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((dr,), dtype=dt),
        "w_a": (jax.random.normal(ks[3], (dr, dr)) * dr ** -0.5).astype(dt),
        "w_x": (jax.random.normal(ks[4], (dr, dr)) * dr ** -0.5).astype(dt),
        "lambda_raw": jnp.full((dr,), 0.65, dtype=jnp.float32),
        "w_out": (jax.random.normal(ks[5], (dr, d)) * dr ** -0.5).astype(dt),
    }


def _rg_lru_coeffs(p, x, cd):
    """x: (..., d_rnn) conv output. Returns (a, b) of h = a*h_prev + b."""
    r = jax.nn.sigmoid((x @ p["w_a"].astype(cd)).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_x"].astype(cd)).astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(p["lambda_raw"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-8)) \
        * (i * x.astype(jnp.float32))
    return a, b


def _causal_conv(x, w, b, cd):
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i].astype(cd) for i in range(k))
    return out + b.astype(cd)


def rglru_forward(p, u, cfg: ArchConfig, state=None):
    """Full-sequence recurrent block. u: (B,S,D). Returns (y, final_state)."""
    cd = cfg.dtype("compute")
    gate = jax.nn.gelu(u @ p["w_gate_branch"].astype(cd))
    x = u @ p["w_rec_in"].astype(cd)
    x = _causal_conv(x, p["conv_w"], p["conv_b"], cd)
    a, bb = _rg_lru_coeffs(p, x, cd)
    if state is not None:
        # fold the incoming state into the first step
        bb = bb.at[:, 0, :].add(a[:, 0, :] * state)

    def op(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(op, (a, bb), axis=1)
    y = (h.astype(cd) * gate) @ p["w_out"].astype(cd)
    return y, h[:, -1, :]


def init_rglru_cache(batch: int, cfg: ArchConfig):
    dr = _d_rnn(cfg)
    return {
        "h": jnp.zeros((batch, dr), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.hybrid.conv_width - 1, dr),
                          dtype=cfg.dtype("compute")),
    }


def rglru_decode(p, u, cache, cfg: ArchConfig):
    """Single-token step. u: (B,1,D)."""
    cd = cfg.dtype("compute")
    gate = jax.nn.gelu(u @ p["w_gate_branch"].astype(cd))
    x = u @ p["w_rec_in"].astype(cd)
    hist = jnp.concatenate([cache["conv"], x.astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"].astype(cd)
    xt = (hist * w[None]).sum(axis=1, keepdims=True) + p["conv_b"].astype(cd)
    a, bb = _rg_lru_coeffs(p, xt, cd)
    h = a[:, 0, :] * cache["h"] + bb[:, 0, :]
    y = (h[:, None, :].astype(cd) * gate) @ p["w_out"].astype(cd)
    return y, {"h": h, "conv": hist[:, 1:, :]}
