"""Mamba-2 SSD (state-space duality) block. [arXiv:2405.21060]

Training/prefill uses the chunked SSD algorithm (lax.scan over chunks with an
(H, P, N) carried state; intra-chunk terms as dense einsums — the "dual"
attention-like form that feeds the MXU). Decode is the O(1) single-step
recurrence. ngroups = 1 (B/C shared across heads).

Recurrence per head (state h in R^{P x N}):
    h_t = exp(dt_t * A) h_{t-1} + dt_t * x_t B_t^T
    y_t = h_t C_t + D * x_t
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


def _dims(cfg: ArchConfig):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    d_conv = d_inner + 2 * s.state_dim   # conv runs over (x, B, C)
    return d_inner, nheads, d_conv


def init_ssm(key, cfg: ArchConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, d_conv = _dims(cfg)
    proj_out = 2 * d_inner + 2 * s.state_dim + nheads   # z, x, B, C, dt
    ks = jax.random.split(key, 5)
    dt = cfg.dtype("param")
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_out)) * d ** -0.5).astype(dt),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, d_conv)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((d_conv,), dtype=dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), dtype=jnp.float32),
        "dt_bias": jnp.zeros((nheads,), dtype=jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (d_inner, d)) * d_inner ** -0.5).astype(dt),
    }


def _split_proj(p, u, cfg: ArchConfig):
    s = cfg.ssm
    d_inner, nheads, _ = _dims(cfg)
    cd = cfg.dtype("compute")
    proj = u @ p["in_proj"].astype(cd)
    z, xbc, dt_raw = jnp.split(
        proj, [d_inner, 2 * d_inner + 2 * s.state_dim], axis=-1)
    return z, xbc, dt_raw


def _causal_conv(xbc, w, b, cd):
    """Depthwise causal conv over time. xbc: (B,S,C); w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i].astype(cd) for i in range(k))
    return jax.nn.silu(out + b.astype(cd))


def ssd_scan(x, dt, A, B, C, chunk, h0=None):
    """Chunked SSD. x: (B,S,H,P); dt: (B,S,H); A: (H,) (positive, decay =
    exp(-dt*A)); B, C: (B,S,N). Returns (y, h_final)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    L = min(chunk, s)
    assert s % L == 0, f"seq {s} not divisible by chunk {L}"
    nc = s // L
    xc = x.reshape(b, nc, L, h, p)
    dtc = dt.reshape(b, nc, L, h)
    Bc = B.reshape(b, nc, L, n)
    Cc = C.reshape(b, nc, L, n)
    if h0 is None:
        h0 = jnp.zeros((b, h, p, n), dtype=jnp.float32)

    def step(hprev, inp):
        xk, dtk, Bk, Ck = inp                      # (B,L,H,P),(B,L,H),(B,L,N)
        a = (-dtk * A).astype(jnp.float32)         # (B,L,H) log decay
        cum = jnp.cumsum(a, axis=1)                # inclusive
        xdt = (xk * dtk[..., None]).astype(jnp.float32)
        # intra-chunk (the "dual" quadratic form, L x L); mask inside the exp
        # so upper-triangle entries never overflow (exp(+big) * 0 = NaN).
        tri = jnp.tril(jnp.ones((L, L), dtype=bool))[None, :, :, None]
        ldiff = cum[:, :, None, :] - cum[:, None, :, :]             # (B,L,L,H)
        decay = jnp.exp(jnp.where(tri, ldiff, -jnp.inf))
        cb = jnp.einsum("bln,bsn->bls", Ck.astype(jnp.float32),
                        Bk.astype(jnp.float32))
        y_intra = jnp.einsum("bls,blsh,bshp->blhp", cb, decay, xdt)
        # inter-chunk from carried state
        y_inter = jnp.einsum("bln,blh,bhpn->blhp", Ck.astype(jnp.float32),
                             jnp.exp(cum), hprev)
        # state update
        sdecay = jnp.exp(cum[:, -1:, :] - cum)                      # (B,L,H)
        hnew = (jnp.exp(cum[:, -1, :])[:, :, None, None] * hprev
                + jnp.einsum("blh,bln,blhp->bhpn", sdecay,
                             Bk.astype(jnp.float32), xdt))
        return hnew, (y_intra + y_inter).astype(x.dtype)

    inputs = (xc.transpose(1, 0, 2, 3, 4), dtc.transpose(1, 0, 2, 3),
              Bc.transpose(1, 0, 2, 3), Cc.transpose(1, 0, 2, 3))
    hf, yc = jax.lax.scan(step, h0, inputs)
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, s, h, p)
    return y, hf


def ssm_forward(p, u, cfg: ArchConfig, h0=None):
    """Full-sequence SSD block. u: (B,S,D). Returns (y, h_final)."""
    s = cfg.ssm
    d_inner, nheads, _ = _dims(cfg)
    cd = cfg.dtype("compute")
    z, xbc, dt_raw = _split_proj(p, u, cfg)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"], cd)
    x, B, C = jnp.split(xbc, [d_inner, d_inner + s.state_dim], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    A = jnp.exp(p["A_log"])
    xh = x.reshape(*x.shape[:2], nheads, s.head_dim)
    y, hf = ssd_scan(xh, dt, A, B, C, s.chunk, h0=h0)
    y = y + p["D"][:, None].astype(cd) * xh
    y = y.reshape(*u.shape[:2], d_inner)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(cd), hf


def init_ssm_cache(batch: int, cfg: ArchConfig):
    s = cfg.ssm
    d_inner, nheads, d_conv = _dims(cfg)
    return {
        "h": jnp.zeros((batch, nheads, s.head_dim, s.state_dim), dtype=jnp.float32),
        "conv": jnp.zeros((batch, s.conv_width - 1, d_conv),
                          dtype=cfg.dtype("compute")),
    }


def ssm_decode(p, u, cache, cfg: ArchConfig):
    """Single-token step. u: (B,1,D). Returns (y, new_cache)."""
    s = cfg.ssm
    d_inner, nheads, _ = _dims(cfg)
    cd = cfg.dtype("compute")
    z, xbc, dt_raw = _split_proj(p, u, cfg)
    # update causal-conv ring: cache holds the previous K-1 inputs
    hist = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)], axis=1)
    w = p["conv_w"].astype(cd)
    conv = (hist * w[None]).sum(axis=1, keepdims=True) + p["conv_b"].astype(cd)
    xbc_t = jax.nn.silu(conv)
    new_conv = hist[:, 1:, :]
    x, B, C = jnp.split(xbc_t, [d_inner, d_inner + s.state_dim], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    A = jnp.exp(p["A_log"])
    xh = x.reshape(x.shape[0], nheads, s.head_dim).astype(jnp.float32)
    decay = jnp.exp(-dt * A)[:, :, None, None]                       # (B,H,1,1)
    inject = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, B[:, 0].astype(jnp.float32))
    h = decay * cache["h"] + inject
    y = jnp.einsum("bhpn,bn->bhp", h, C[:, 0].astype(jnp.float32))
    y = y + p["D"][:, None] * xh
    y = y.reshape(u.shape[0], 1, d_inner).astype(cd)
    y = y * jax.nn.silu(z)
    return y @ p["out_proj"].astype(cd), {"h": h, "conv": new_conv}
