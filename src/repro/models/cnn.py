"""The paper's own CNN workloads (CaffeNet/AlexNet-family, LeNet) with the
conv-phase / FC-phase split made explicit (paper §II-C, Fig. 1) — the split
drives the hardware-efficiency model and the merged-FC ("sync head") update.

Conv layers run through ``repro.kernels.lowering_conv.ops`` — the paper's
§III batched lowering with the custom batched-GEMM backward — and the
configs default to it (``conv_impl="lowering"``): this is the training hot
path, not a demo. ``conv_impl``:

  "lowering"            lowering + GEMM with the custom VJP (XLA form, the
                        CPU training path; docs/lowering_conv.md)
  "lowering_interpret"  the Pallas kernels (interpret mode on CPU), tiles
                        from the per-layer autotune cache
  "lowering_autodiff"   the same algorithm under generic XLA autodiff
                        (benchmark baseline)
  "xla"                 jax.lax.conv_general_dilated

The first conv layer is fed by data, so its input gradient is skipped
(``needs_dgrad=False`` — Caffe's ``propagate_down=false``; generic
autodiff gets the same from DCE).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    features: int
    kernel: int
    stride: int = 1
    pool: int = 1          # max-pool window/stride after the conv (1 = none)


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    image_size: int
    in_channels: int
    num_classes: int
    convs: Tuple[ConvSpec, ...]
    fc_dims: Tuple[int, ...]
    # xla | lowering | lowering_interpret | lowering_autodiff (see module
    # docstring); "lowering" is the real training path
    conv_impl: str = "lowering"
    source: str = ""


LENET = CNNConfig(
    name="lenet", image_size=28, in_channels=1, num_classes=10,
    convs=(ConvSpec(20, 5, pool=2), ConvSpec(50, 5, pool=2)),
    fc_dims=(500,),
    source="LeCun 1998 / Caffe MNIST tutorial (paper Fig. 8)")

# CaffeNet geometry (paper's main workload), scaled-down option for CPU runs.
CAFFENET = CNNConfig(
    name="caffenet", image_size=227, in_channels=3, num_classes=1000,
    convs=(ConvSpec(96, 11, stride=4, pool=2), ConvSpec(256, 5, pool=2),
           ConvSpec(384, 3), ConvSpec(384, 3), ConvSpec(256, 3, pool=2)),
    fc_dims=(4096, 4096),
    source="Krizhevsky 2012 / BVLC reference CaffeNet (paper §VI-A)")

CIFAR_NET = CNNConfig(
    name="cifarnet", image_size=32, in_channels=3, num_classes=10,
    convs=(ConvSpec(32, 5, pool=2), ConvSpec(32, 5, pool=2), ConvSpec(64, 5, pool=2)),
    fc_dims=(64,),
    source="Caffe CIFAR-10 tutorial (paper Fig. 8)")

CNN_CONFIGS = {c.name: c for c in (LENET, CAFFENET, CIFAR_NET)}


def get_cnn_config(name: str) -> CNNConfig:
    try:
        return CNN_CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown CNN arch {name!r}; "
                       f"known: {sorted(CNN_CONFIGS)}") from None


# Per-arch smoke geometry: shrink image/channels/classes but KEEP each
# family's defining structure — caffenet's strided big-kernel conv1,
# cifarnet's three pooled convs — so the smoke runs exercise stride > 1
# and pooling the way the full archs do (the conv-backward test matrix
# and the throughput bench both key off this).
_SMOKE_GEOMETRY = {
    "lenet": dict(image_size=16, convs=(ConvSpec(8, 5, pool=2),
                                        ConvSpec(16, 3)), fc_dims=(32,)),
    "caffenet": dict(image_size=33, convs=(ConvSpec(16, 7, stride=2, pool=2),
                                           ConvSpec(32, 3)), fc_dims=(64,)),
    "cifarnet": dict(image_size=20, convs=(ConvSpec(8, 5, pool=2),
                                           ConvSpec(16, 3, pool=2)),
                     fc_dims=(16,)),
}


def get_cnn_smoke_config(name: str) -> CNNConfig:
    """CPU-runnable reduced same-family config (the CNN counterpart of
    ``configs.get_smoke_config``): shrink the image but keep the conv/FC
    phase split AND the family's conv structure (strides/pools), so the
    merged-FC head semantics and the conv-backward paths stay exercised."""
    base = get_cnn_config(name)
    return dataclasses.replace(
        base, name=f"{base.name}-smoke", num_classes=4,
        **_SMOKE_GEOMETRY[base.name])


def _conv(x, w, b, stride, impl, needs_dgrad=True):
    if impl.startswith("lowering"):
        # _traced forms: the loss is always inside the engine's jit (and
        # possibly its group-vmap) — a nested jit there costs ~2x on CPU
        from repro.kernels.lowering_conv import autotune, ops as lc_ops
        if impl.endswith("interpret"):    # Pallas kernels, interpret on CPU
            bp, rb = autotune.cached_tiles(x.shape, w.shape, stride)
            y = lc_ops.lowering_conv_traced(x, w, stride=stride, bp=bp,
                                            rb=rb, interpret=True,
                                            needs_dgrad=needs_dgrad)
        elif impl.endswith("autodiff"):   # generic-autodiff baseline
            from repro.kernels.lowering_conv.ref import lowered_conv_ref
            y = lowered_conv_ref(x, w, stride=stride)
        else:                             # custom VJP through XLA
            y = lc_ops.lowering_conv_xla_traced(x, w, stride=stride,
                                                needs_dgrad=needs_dgrad)
    else:
        y = jax.lax.conv_general_dilated(
            x, w, window_strides=(stride, stride), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _maxpool(x, k):
    """Non-overlapping max pool. reshape+max instead of reduce_window:
    XLA CPU lowers reduce_window (and its select-and-scatter backward) to
    slow scalar loops that dominated the whole CNN step; the reshape form
    is a dense vectorized max with a cheap backward. VALID semantics:
    trailing rows/cols that don't fill a window are dropped."""
    if k == 1:
        return x
    b, h, w, c = x.shape
    x = x[:, :h // k * k, :w // k * k, :]
    return x.reshape(b, h // k, k, w // k, k, c).max(axis=(2, 4))


def init_params(key, cfg: CNNConfig):
    """Returns {"conv": [...], "fc": [...]} — the paper's two phases."""
    keys = jax.random.split(key, len(cfg.convs) + len(cfg.fc_dims) + 1)
    conv_params = []
    c_in = cfg.in_channels
    size = cfg.image_size
    for i, spec in enumerate(cfg.convs):
        w = jax.random.normal(keys[i], (spec.kernel, spec.kernel, c_in,
                                        spec.features)) * 0.01
        conv_params.append({"w": w, "b": jnp.zeros((spec.features,))})
        size = (size - spec.kernel) // spec.stride + 1
        size = size // spec.pool if spec.pool > 1 else size
        c_in = spec.features
    flat = size * size * c_in
    fc_params = []
    dims = (flat,) + tuple(cfg.fc_dims) + (cfg.num_classes,)
    for j in range(len(dims) - 1):
        k = keys[len(cfg.convs) + j]
        w = jax.random.normal(k, (dims[j], dims[j + 1])) * (dims[j] ** -0.5)
        fc_params.append({"w": w, "b": jnp.zeros((dims[j + 1],))})
    return {"conv": conv_params, "fc": fc_params}


def forward(params, images, cfg: CNNConfig):
    """images: (B,H,W,C) -> logits (B,num_classes)."""
    x = images
    for i, (spec, p) in enumerate(zip(cfg.convs, params["conv"])):
        # layer 0 is fed by data: no input gradient (see module docstring)
        x = jax.nn.relu(_conv(x, p["w"], p["b"], spec.stride, cfg.conv_impl,
                              needs_dgrad=i > 0))
        x = _maxpool(x, spec.pool)
    x = x.reshape(x.shape[0], -1)
    for i, p in enumerate(params["fc"]):
        x = x @ p["w"] + p["b"]
        if i < len(params["fc"]) - 1:
            x = jax.nn.relu(x)
    return x


def loss_fn(params, batch, cfg: CNNConfig):
    logits = forward(params, batch["images"], cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1).mean()


def conv_layer_shapes(cfg: CNNConfig, batch_size: int):
    """[(x_shape, w_shape, stride), ...] for each conv layer — the shapes
    the tile autotuner and the conv-backward tests iterate."""
    out = []
    c_in, size = cfg.in_channels, cfg.image_size
    for spec in cfg.convs:
        out.append(((batch_size, size, size, c_in),
                    (spec.kernel, spec.kernel, c_in, spec.features),
                    spec.stride))
        size = (size - spec.kernel) // spec.stride + 1
        size = size // spec.pool if spec.pool > 1 else size
        c_in = spec.features
    return out


def autotune_conv_tiles(cfg: CNNConfig, batch_size: int, **kw):
    """Probe and cache (b_p, r_b) for every conv layer of ``cfg`` (only
    meaningful for conv_impl="lowering_interpret", which reads the cache).
    Returns {layer_index: (bp, rb)}."""
    from repro.kernels.lowering_conv import autotune
    choices = {}
    for i, (x_shape, w_shape, stride) in enumerate(
            conv_layer_shapes(cfg, batch_size)):
        choices[i] = autotune.autotune_tiles(x_shape, w_shape, stride, **kw)
    return choices


def head_filter(path) -> bool:
    """True for FC-phase params — the paper's merged-FC servers update these
    synchronously (zero staleness)."""
    return any(getattr(p, "key", getattr(p, "name", None)) == "fc"
               or (isinstance(p, jax.tree_util.DictKey) and p.key == "fc")
               for p in path)
