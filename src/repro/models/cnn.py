"""The paper's own CNN workloads (CaffeNet/AlexNet-family, LeNet) with the
conv-phase / FC-phase split made explicit (paper §II-C, Fig. 1) — the split
drives the hardware-efficiency model and the merged-FC ("sync head") update.

Conv layers run through ``repro.kernels.lowering_conv.ops`` when
``conv_impl="lowering"`` (paper §III batched lowering, Pallas on TPU) or
``jax.lax.conv_general_dilated`` (XLA) otherwise.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    features: int
    kernel: int
    stride: int = 1
    pool: int = 1          # max-pool window/stride after the conv (1 = none)


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str
    image_size: int
    in_channels: int
    num_classes: int
    convs: Tuple[ConvSpec, ...]
    fc_dims: Tuple[int, ...]
    conv_impl: str = "xla"            # xla | lowering | lowering_interpret
    source: str = ""


LENET = CNNConfig(
    name="lenet", image_size=28, in_channels=1, num_classes=10,
    convs=(ConvSpec(20, 5, pool=2), ConvSpec(50, 5, pool=2)),
    fc_dims=(500,),
    source="LeCun 1998 / Caffe MNIST tutorial (paper Fig. 8)")

# CaffeNet geometry (paper's main workload), scaled-down option for CPU runs.
CAFFENET = CNNConfig(
    name="caffenet", image_size=227, in_channels=3, num_classes=1000,
    convs=(ConvSpec(96, 11, stride=4, pool=2), ConvSpec(256, 5, pool=2),
           ConvSpec(384, 3), ConvSpec(384, 3), ConvSpec(256, 3, pool=2)),
    fc_dims=(4096, 4096),
    source="Krizhevsky 2012 / BVLC reference CaffeNet (paper §VI-A)")

CIFAR_NET = CNNConfig(
    name="cifarnet", image_size=32, in_channels=3, num_classes=10,
    convs=(ConvSpec(32, 5, pool=2), ConvSpec(32, 5, pool=2), ConvSpec(64, 5, pool=2)),
    fc_dims=(64,),
    source="Caffe CIFAR-10 tutorial (paper Fig. 8)")

CNN_CONFIGS = {c.name: c for c in (LENET, CAFFENET, CIFAR_NET)}


def get_cnn_config(name: str) -> CNNConfig:
    try:
        return CNN_CONFIGS[name]
    except KeyError:
        raise KeyError(f"unknown CNN arch {name!r}; "
                       f"known: {sorted(CNN_CONFIGS)}") from None


def get_cnn_smoke_config(name: str) -> CNNConfig:
    """CPU-runnable reduced same-family config (the CNN counterpart of
    ``configs.get_smoke_config``): shrink the image, keep the conv/FC
    phase split so the merged-FC head semantics stay exercised."""
    base = get_cnn_config(name)
    return dataclasses.replace(
        base, name=f"{base.name}-smoke", image_size=12, num_classes=4,
        convs=(ConvSpec(8, 3, pool=2),), fc_dims=(16,))


def _conv(x, w, b, stride, impl):
    if impl.startswith("lowering"):
        from repro.kernels.lowering_conv import ops as lc_ops
        if impl.endswith("interpret"):    # Pallas kernel, interpret on CPU
            y = lc_ops.lowering_conv(x, w, stride=stride, interpret=True)
        else:                             # same algorithm through XLA
            y = lc_ops.lowering_conv_xla(x, w, stride=stride)
    else:
        y = jax.lax.conv_general_dilated(
            x, w, window_strides=(stride, stride), padding="VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _maxpool(x, k):
    if k == 1:
        return x
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, k, k, 1), (1, k, k, 1), "VALID")


def init_params(key, cfg: CNNConfig):
    """Returns {"conv": [...], "fc": [...]} — the paper's two phases."""
    keys = jax.random.split(key, len(cfg.convs) + len(cfg.fc_dims) + 1)
    conv_params = []
    c_in = cfg.in_channels
    size = cfg.image_size
    for i, spec in enumerate(cfg.convs):
        w = jax.random.normal(keys[i], (spec.kernel, spec.kernel, c_in,
                                        spec.features)) * 0.01
        conv_params.append({"w": w, "b": jnp.zeros((spec.features,))})
        size = (size - spec.kernel) // spec.stride + 1
        size = size // spec.pool if spec.pool > 1 else size
        c_in = spec.features
    flat = size * size * c_in
    fc_params = []
    dims = (flat,) + tuple(cfg.fc_dims) + (cfg.num_classes,)
    for j in range(len(dims) - 1):
        k = keys[len(cfg.convs) + j]
        w = jax.random.normal(k, (dims[j], dims[j + 1])) * (dims[j] ** -0.5)
        fc_params.append({"w": w, "b": jnp.zeros((dims[j + 1],))})
    return {"conv": conv_params, "fc": fc_params}


def forward(params, images, cfg: CNNConfig):
    """images: (B,H,W,C) -> logits (B,num_classes)."""
    x = images
    for spec, p in zip(cfg.convs, params["conv"]):
        x = jax.nn.relu(_conv(x, p["w"], p["b"], spec.stride, cfg.conv_impl))
        x = _maxpool(x, spec.pool)
    x = x.reshape(x.shape[0], -1)
    for i, p in enumerate(params["fc"]):
        x = x @ p["w"] + p["b"]
        if i < len(params["fc"]) - 1:
            x = jax.nn.relu(x)
    return x


def loss_fn(params, batch, cfg: CNNConfig):
    logits = forward(params, batch["images"], cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1).mean()


def head_filter(path) -> bool:
    """True for FC-phase params — the paper's merged-FC servers update these
    synchronously (zero staleness)."""
    return any(getattr(p, "key", getattr(p, "name", None)) == "fc"
               or (isinstance(p, jax.tree_util.DictKey) and p.key == "fc")
               for p in path)
