from repro.models import cnn, layers, moe, rglru, ssm, transformer

__all__ = ["cnn", "layers", "moe", "rglru", "ssm", "transformer"]
