"""Mixture-of-Experts layer: top-k router with capacity-based dispatch
(Switch/GShard style), optional shared experts (Qwen-MoE), and router
load-balance auxiliary loss.

TPU-shaped implementation choices:
  - dispatch is **batch-local and sequence-chunked** (lax.scan over chunks of
    ``MOE_CHUNK`` tokens): the position-in-expert cumsum never crosses a
    shard boundary, so under batch sharding the whole dispatch lowers
    without cross-chip scans, and the (tokens, E, capacity) one-hots stay
    VMEM-scale. Capacity is capped at ``MAX_CAPACITY`` (token dropping,
    standard for capacity-factor MoE).
  - expert FFN hidden dim is the tensor-sharded axis (always divisible by
    the model axis, unlike expert count: 60 experts vs 16-wide axis).
Expert-parallel all-to-all is a recorded beyond-paper optimization candidate
(EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

CAPACITY_FACTOR = 1.25
MOE_CHUNK = 4096
MAX_CAPACITY = 1024


def init_moe(key, cfg: ArchConfig):
    assert cfg.moe is not None
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    dt = cfg.dtype("param")
    ks = jax.random.split(key, 5)
    p = {
        "router": (jax.random.normal(ks[0], (d, e)) * d ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f)) * d ** -0.5).astype(dt),
        "w_up": (jax.random.normal(ks[2], (e, d, f)) * d ** -0.5).astype(dt),
        "w_down": (jax.random.normal(ks[3], (e, f, d)) * f ** -0.5).astype(dt),
    }
    if m.num_shared_experts > 0:
        fs = m.num_shared_experts * f
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": (jax.random.normal(ks2[0], (d, fs)) * d ** -0.5).astype(dt),
            "w_up": (jax.random.normal(ks2[1], (d, fs)) * d ** -0.5).astype(dt),
            "w_down": (jax.random.normal(ks2[2], (fs, d)) * fs ** -0.5).astype(dt),
        }
    return p


def capacity(tokens_per_row: int, cfg: ArchConfig) -> int:
    m = cfg.moe
    c = int(m.top_k * tokens_per_row * CAPACITY_FACTOR / m.num_experts)
    return max(1, min(c, MAX_CAPACITY))


def _chunk_moe(p, xk, cfg: ArchConfig):
    """One chunk. xk: (B, L, D) -> (y, aux_stats)."""
    m = cfg.moe
    cd = cfg.dtype("compute")
    b, L, d = xk.shape
    e, k = m.num_experts, m.top_k
    cap = capacity(L, cfg)

    logits = (xk.astype(jnp.float32) @ p["router"])          # (B,L,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)            # (B,L,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(axis=-1, keepdims=True), 1e-9)

    from repro.sharding.rules import constrain_batch
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)    # (B,L,k,E)
    flat = constrain_batch(onehot.reshape(b, L * k, e))
    # position within each expert's buffer (cumsum stays inside the row ->
    # batch-local under sharding)
    pos = jnp.cumsum(flat, axis=1) * flat - 1                # (B,Lk,E)
    keep = (pos < cap) & (flat > 0)
    pos_oh = jax.nn.one_hot(pos, cap, dtype=cd) * keep[..., None]  # (B,Lk,E,C)
    pos_oh = constrain_batch(pos_oh)
    gates_flat = jnp.repeat(gate_vals.reshape(b, L, k), 1, axis=-1) \
                    .reshape(b, L * k).astype(cd)
    x_rep = constrain_batch(jnp.repeat(xk, k, axis=1))       # (B,Lk,D)

    xin = constrain_batch(
        jnp.einsum("btec,btd->becd", pos_oh, x_rep))         # (B,E,C,D)
    gate = jax.nn.silu(jnp.einsum("becd,edf->becf", xin, p["w_gate"].astype(cd)))
    up = jnp.einsum("becd,edf->becf", xin, p["w_up"].astype(cd))
    out = jnp.einsum("becf,efd->becd", gate * up, p["w_down"].astype(cd))
    # combine back: weight each (token, choice) by its gate
    y = jnp.einsum("btec,bt,becd->btd", pos_oh, gates_flat, out)
    y = y.reshape(b, L, k, d).sum(axis=2)

    # GShard load-balance stats (summed over chunks by the caller)
    frac_tokens = onehot.sum(axis=2).astype(jnp.float32).mean(axis=(0, 1))
    mean_prob = probs.mean(axis=(0, 1))
    return y, (frac_tokens, mean_prob)


def moe_forward(p, x, cfg: ArchConfig, chunk: int = MOE_CHUNK):
    """x: (B, S, D). Returns (y, aux_loss)."""
    m = cfg.moe
    cd = cfg.dtype("compute")
    b, s, d = x.shape
    L = min(chunk, s)
    e = m.num_experts

    if s % L or s == L:
        y, (ft, mp) = _chunk_moe(p, x, cfg)
        aux = e * jnp.sum(ft * mp)
    else:
        nc = s // L
        xc = x.reshape(b, nc, L, d).transpose(1, 0, 2, 3)

        def body(carry, xk):
            y, (ft, mp) = _chunk_moe(p, xk, cfg)
            return (carry[0] + ft, carry[1] + mp), y
        (ft, mp), yc = jax.lax.scan(
            body, (jnp.zeros((e,)), jnp.zeros((e,))), xc)
        y = yc.transpose(1, 0, 2, 3).reshape(b, s, d)
        aux = e * jnp.sum((ft / nc) * (mp / nc))

    if "shared" in p:
        sp = p["shared"]
        xt = x.reshape(b * s, d)
        h = jax.nn.silu(xt @ sp["w_gate"].astype(cd)) * (xt @ sp["w_up"].astype(cd))
        y = y + (h @ sp["w_down"].astype(cd)).reshape(b, s, d)

    return y, aux * m.router_aux_weight
