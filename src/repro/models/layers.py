"""Core transformer layers: RMSNorm, RoPE, GQA attention (full / sliding /
cross / decode-with-cache), SwiGLU & GeLU MLPs.

Pure-functional: params are nested dicts of jnp arrays; every init_* has a
matching apply function. Attention defaults to a memory-efficient chunked
(flash-semantics) implementation in plain XLA; the Pallas kernel in
``repro.kernels.flash_attention`` is the TPU fast path (selected via
``attn_impl``).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

# Threshold at/above which train/prefill attention switches to the chunked
# (flash-semantics) implementation to avoid materializing S^2 scores.
CHUNKED_ATTN_THRESHOLD = 4096
KV_CHUNK = 1024


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def init_rms_norm(d: int, dtype) -> jax.Array:
    return jnp.zeros((d,), dtype=dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, hd); positions: (..., S)."""
    if theta <= 0.0:
        return x
    hd = x.shape[-1]
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, hd, 2, dtype=jnp.float32) / hd)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                                 # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d: int) -> jax.Array:
    """Whisper-style sinusoidal position embeddings (fp32)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    inv = jnp.exp(-math.log(10_000.0) * dim / d)
    ang = pos * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, *, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    dt = cfg.dtype("param")
    std = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, h, hd)) * std).astype(dt),
        "wk": (jax.random.normal(ks[1], (d, kv, hd)) * std).astype(dt),
        "wv": (jax.random.normal(ks[2], (d, kv, hd)) * std).astype(dt),
        "wo": (jax.random.normal(ks[3], (h, hd, d)) * std).astype(dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((h, hd), dtype=dt)
        p["bk"] = jnp.zeros((kv, hd), dtype=dt)
        p["bv"] = jnp.zeros((kv, hd), dtype=dt)
    return p


def _project_qkv(p, x, kv_src, cfg: ArchConfig):
    from repro.sharding.rules import maybe_replicate_for_decode
    cd = cfg.dtype("compute")
    x = maybe_replicate_for_decode(x)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
    src = x if kv_src is None else kv_src
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"].astype(cd))
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    return q, k, v


def _grouped_scores(q, k):
    """q (B,Sq,H,hd), k (B,Sk,K,hd) with H = K*G -> scores (B,K,G,Sq,Sk)."""
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, hd)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k) / math.sqrt(hd)


def _apply_scores(w, v):
    """w (B,K,G,Sq,Sk), v (B,Sk,K,hd) -> (B,Sq,H,hd)."""
    b, kh, g, sq, sk = w.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, v)
    return out.reshape(b, sq, kh * g, v.shape[-1])


def _mask_bias(sq, sk, q_offset, *, causal: bool, window: Optional[int]):
    """Additive mask bias (Sq,Sk) in fp32. q position i attends to k position j."""
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    ok = jnp.ones((sq, sk), dtype=bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        ok &= kpos[None, :] > (qpos[:, None] - window)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)


def full_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                   q_offset: int = 0):
    """Reference O(S^2)-memory attention (grouped-query)."""
    scores = _grouped_scores(q, k).astype(jnp.float32)
    bias = _mask_bias(q.shape[1], k.shape[1], q_offset, causal=causal, window=window)
    w = jax.nn.softmax(scores + bias, axis=-1).astype(q.dtype)
    return _apply_scores(w, v)


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                      kv_chunk: int = KV_CHUNK):
    """Flash-semantics attention: lax.scan over KV chunks with running
    max/denominator. O(Sq * kv_chunk) live score memory."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    kh = k.shape[2]
    g = h // kh
    if sk % kv_chunk != 0:
        return full_attention(q, k, v, causal=causal, window=window)
    nchunks = sk // kv_chunk
    qg = q.reshape(b, sq, kh, g, hd)
    kc = k.reshape(b, nchunks, kv_chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, kv_chunk, kh, hd).transpose(1, 0, 2, 3, 4)
    qpos = jnp.arange(sq)

    def step(carry, inp):
        m_prev, l_prev, acc = carry
        idx, kb, vb = inp
        scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, kb).astype(jnp.float32)
        scores = scores / math.sqrt(hd)
        kpos = idx * kv_chunk + jnp.arange(kv_chunk)
        ok = jnp.ones((sq, kv_chunk), dtype=bool)
        if causal:
            ok &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            ok &= kpos[None, :] > (qpos[:, None] - window)
        scores = scores + jnp.where(ok, 0.0, -1e30)
        m_new = jnp.maximum(m_prev, scores.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l_prev * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(q.dtype), vb)
        acc = acc * alpha[..., None].astype(q.dtype) + pv
        return (m_new, l_new, acc), None

    from repro.sharding.rules import constrain_batch
    m0 = constrain_batch(jnp.full((b, kh, g, sq), -jnp.inf, dtype=jnp.float32))
    l0 = constrain_batch(jnp.zeros((b, kh, g, sq), dtype=jnp.float32))
    acc0 = constrain_batch(jnp.zeros((b, kh, g, sq, hd), dtype=q.dtype))
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, acc0), (jnp.arange(nchunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None].astype(q.dtype)
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)


def attention_forward(p, x, cfg: ArchConfig, *, positions=None, causal=True,
                      window: Optional[int] = None, kv_src=None,
                      attn_impl: str = "xla"):
    """Train/prefill attention over a whole sequence. Returns (out, (k, v))
    so prefill can populate a cache."""
    from repro.sharding.rules import (constrain_batch, constrain_kv_seq,
                                      seq_parallel_enabled)
    cd = cfg.dtype("compute")
    q, k, v = _project_qkv(p, x, kv_src, cfg)
    q, k, v = constrain_batch(q), constrain_batch(k), constrain_batch(v)
    seq_par = seq_parallel_enabled() and kv_src is None
    if seq_par:
        # hillclimb variant: distribute attention over the tensor axis by
        # sharding K/V on sequence (heads needn't divide the axis). Q-side
        # sharding was tried and refuted — the backward pass re-gathers the
        # whole residual per layer (18.4 s vs 5.3 s; EXPERIMENTS.md §Perf).
        k, v = constrain_kv_seq(k), constrain_kv_seq(v)
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    if kv_src is None:  # self-attention gets RoPE
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if attn_impl == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, k, v, causal=causal, window=window)
    elif (x.shape[1] >= CHUNKED_ATTN_THRESHOLD and kv_src is None
          and not seq_par):
        # chunked flash-semantics scan; under seq-parallel the KV-seq dim is
        # mesh-sharded and the scan reslicing fights GSPMD — use the direct
        # form whose scores stay sharded on Sk instead (§Perf)
        out = chunked_attention(q, k, v, causal=causal, window=window)
    else:
        out = full_attention(q, k, v, causal=causal, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return y, (k, v)


def attention_decode(p, x, cache, pos, cfg: ArchConfig, *,
                     window: Optional[int] = None, kv_src_cache=None):
    """Single-token decode. x: (B,1,D). cache: {"k","v"}: (B,W,K,hd) ring
    buffer (W = window or full seq). pos: scalar int32 absolute position.
    Returns (out, new_cache)."""
    cd = cfg.dtype("compute")
    if kv_src_cache is not None:
        # cross-attention: static cache, no update
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(cd))
        if "bq" in p:
            q = q + p["bq"].astype(cd)
        out = full_attention(q, kv_src_cache["k"], kv_src_cache["v"], causal=False)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd)), cache
    q, k, v = _project_qkv(p, x, None, cfg)
    posb = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)
    W = cache["k"].shape[1]
    slot = pos % W if window is not None else pos
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    # validity: absolute position of ring slot s
    slots = jnp.arange(W)
    if window is not None:
        base = pos - (pos % W)
        abs_pos = jnp.where(slots <= (pos % W), base + slots, base - W + slots)
    else:
        abs_pos = slots
    valid = (abs_pos <= pos) & (abs_pos >= 0)
    if window is not None:
        valid &= abs_pos > (pos - window)
    scores = _grouped_scores(q, ck.astype(cd)).astype(jnp.float32)
    scores = scores + jnp.where(valid, 0.0, -1e30)[None, None, None, None, :]
    w = jax.nn.softmax(scores, axis=-1).astype(cd)
    out = _apply_scores(w, cv.astype(cd))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return y, {"k": ck, "v": cv}


def init_attn_cache(batch: int, cfg: ArchConfig, seq_len: int,
                    window: Optional[int] = None):
    W = min(window, seq_len) if window is not None else seq_len
    kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    dt = cfg.dtype("compute")
    return {"k": jnp.zeros((batch, W, kv, hd), dtype=dt),
            "v": jnp.zeros((batch, W, kv, hd), dtype=dt)}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ArchConfig, d_ff: Optional[int] = None):
    d = cfg.d_model
    f = d_ff if d_ff is not None else cfg.d_ff
    dt = cfg.dtype("param")
    ks = jax.random.split(key, 3)
    p = {"w_up": (jax.random.normal(ks[1], (d, f)) * d ** -0.5).astype(dt),
         "w_down": (jax.random.normal(ks[2], (f, d)) * f ** -0.5).astype(dt)}
    if cfg.act == "swiglu":
        p["w_gate"] = (jax.random.normal(ks[0], (d, f)) * d ** -0.5).astype(dt)
    return p


def mlp_forward(p, x, cfg: ArchConfig):
    from repro.sharding.rules import maybe_replicate_for_decode
    cd = cfg.dtype("compute")
    x = maybe_replicate_for_decode(x)
    up = x @ p["w_up"].astype(cd)
    if cfg.act == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"].astype(cd)) * up
    else:
        h = jax.nn.gelu(up)
    return h @ p["w_down"].astype(cd)


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ArchConfig):
    dt = cfg.dtype("param")
    ks = jax.random.split(key, 2)
    p = {"tok": (jax.random.normal(ks[0], (cfg.vocab_size, cfg.d_model)) * 0.02).astype(dt)}
    if not cfg.tie_embeddings:
        p["unembed"] = (jax.random.normal(ks[1], (cfg.d_model, cfg.vocab_size))
                        * cfg.d_model ** -0.5).astype(dt)
    return p


def embed(p, tokens, cfg: ArchConfig):
    return p["tok"].astype(cfg.dtype("compute"))[tokens]


def unembed(p, x, cfg: ArchConfig):
    w = p["unembed"] if "unembed" in p else p["tok"].T
    return (x @ w.astype(cfg.dtype("compute"))).astype(jnp.float32)
