"""Unified language-model assembly for all assigned architecture families.

arch_type:
  dense  — (norm, GQA attn, norm, MLP) x L
  moe    — (norm, GQA attn, norm, MoE) x L
  ssm    — (norm, SSD) x L                              (attention-free)
  hybrid — Griffin super-blocks (rec, rec, local-attn) cyclic
  vlm    — decoder with a cross-attn layer every `cross_attn_every` layers
  encdec — Whisper: encoder (non-causal) + decoder (causal + cross)

All homogeneous stacks run under ``lax.scan`` over stacked layer params so
compile time is depth-independent; blocks are wrapped in ``jax.checkpoint``
when cfg.remat. Params are nested dicts; caches mirror the layer structure.

API:
  init_params(key, cfg)                         -> params
  forward(params, batch, cfg, return_cache=...) -> (logits, aux, cache|None)
  init_cache(cfg, batch, seq_len)               -> cache pytree (decode)
  decode_step(params, cache, tokens, pos, cfg)  -> (logits, new_cache)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import rglru as R
from repro.models import ssm as S
from repro.sharding.rules import constrain_batch


# ---------------------------------------------------------------------------
# Per-block init
# ---------------------------------------------------------------------------

def _init_dense_block(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_rms_norm(cfg.d_model, cfg.dtype("param")),
            "attn": L.init_attention(k1, cfg),
            "ln2": L.init_rms_norm(cfg.d_model, cfg.dtype("param")),
            "mlp": L.init_mlp(k2, cfg)}


def _init_moe_block(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_rms_norm(cfg.d_model, cfg.dtype("param")),
            "attn": L.init_attention(k1, cfg),
            "ln2": L.init_rms_norm(cfg.d_model, cfg.dtype("param")),
            "moe": M.init_moe(k2, cfg)}


def _init_ssm_block(key, cfg: ArchConfig):
    return {"ln": L.init_rms_norm(cfg.d_model, cfg.dtype("param")),
            "ssm": S.init_ssm(key, cfg)}


def _init_rec_block(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_rms_norm(cfg.d_model, cfg.dtype("param")),
            "rec": R.init_rglru_block(k1, cfg),
            "ln2": L.init_rms_norm(cfg.d_model, cfg.dtype("param")),
            "mlp": L.init_mlp(k2, cfg)}


def _init_cross_block(key, cfg: ArchConfig):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_rms_norm(cfg.d_model, cfg.dtype("param")),
            "cross": L.init_attention(k1, cfg, cross=True),
            "ln2": L.init_rms_norm(cfg.d_model, cfg.dtype("param")),
            "mlp": L.init_mlp(k2, cfg)}


def _init_encdec_dec_block(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.init_rms_norm(cfg.d_model, cfg.dtype("param")),
            "attn": L.init_attention(k1, cfg),
            "ln2": L.init_rms_norm(cfg.d_model, cfg.dtype("param")),
            "cross": L.init_attention(k2, cfg, cross=True),
            "ln3": L.init_rms_norm(cfg.d_model, cfg.dtype("param")),
            "mlp": L.init_mlp(k3, cfg)}


def _stack(init_fn, key, n, cfg):
    return jax.vmap(lambda k: init_fn(k, cfg))(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# init_params
# ---------------------------------------------------------------------------

def _hybrid_counts(cfg: ArchConfig):
    plen = len(cfg.hybrid.pattern)
    n_super = cfg.num_layers // plen
    n_rem = cfg.num_layers - n_super * plen
    return plen, n_super, n_rem


def _vlm_counts(cfg: ArchConfig):
    per = cfg.cross_attn_every
    n_super = cfg.num_layers // per
    n_rem = cfg.num_layers - n_super * per
    return per, n_super, n_rem


def init_params(key, cfg: ArchConfig):
    kemb, kblocks, kextra, kfin = jax.random.split(key, 4)
    params = {"embed": L.init_embed(kemb, cfg),
              "ln_f": L.init_rms_norm(cfg.d_model, cfg.dtype("param"))}
    t = cfg.arch_type
    if t in ("dense",):
        params["blocks"] = _stack(_init_dense_block, kblocks, cfg.num_layers, cfg)
    elif t == "moe":
        params["blocks"] = _stack(_init_moe_block, kblocks, cfg.num_layers, cfg)
    elif t == "ssm":
        params["blocks"] = _stack(_init_ssm_block, kblocks, cfg.num_layers, cfg)
    elif t == "hybrid":
        plen, n_super, n_rem = _hybrid_counts(cfg)
        n_rec = sum(1 for x in cfg.hybrid.pattern if x == "rec")
        params["super"] = {
            "rec": _stack(lambda k, c: _stack(_init_rec_block, k, n_rec, c),
                          kblocks, n_super, cfg),
            "attn": _stack(_init_dense_block, kextra, n_super, cfg),
        }
        if n_rem:
            params["rem"] = _stack(_init_rec_block, kfin, n_rem, cfg)
    elif t == "vlm":
        per, n_super, n_rem = _vlm_counts(cfg)
        params["super"] = {
            "self": _stack(lambda k, c: _stack(_init_dense_block, k, per - 1, c),
                           kblocks, n_super, cfg),
            "cross": _stack(_init_cross_block, kextra, n_super, cfg),
        }
        if n_rem:
            params["rem"] = _stack(_init_dense_block, kfin, n_rem, cfg)
    elif t == "encdec":
        params["enc"] = _stack(_init_dense_block, kblocks,
                               cfg.encoder_layers, cfg)
        params["enc_ln"] = L.init_rms_norm(cfg.d_model, cfg.dtype("param"))
        params["blocks"] = _stack(_init_encdec_dec_block, kextra,
                                  cfg.num_layers, cfg)
    else:
        raise ValueError(t)
    return params


# ---------------------------------------------------------------------------
# Block applications (x -> x), written to be scanned
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg: ArchConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


def _dense_block(bp, x, cfg, *, window=None, attn_impl="xla", collect=False):
    h, kv = L.attention_forward(bp["attn"], L.rms_norm(x, bp["ln1"], cfg.norm_eps),
                                cfg, window=window, attn_impl=attn_impl)
    x = x + h
    x = x + L.mlp_forward(bp["mlp"], L.rms_norm(x, bp["ln2"], cfg.norm_eps), cfg)
    return constrain_batch(x), (kv if collect else None)


def _moe_block(bp, x, cfg, *, window=None, attn_impl="xla", collect=False):
    h, kv = L.attention_forward(bp["attn"], L.rms_norm(x, bp["ln1"], cfg.norm_eps),
                                cfg, window=window, attn_impl=attn_impl)
    x = x + h
    y, aux = M.moe_forward(bp["moe"], L.rms_norm(x, bp["ln2"], cfg.norm_eps), cfg)
    return constrain_batch(x + y), aux, (kv if collect else None)


def _ssm_block(bp, x, cfg, collect=False):
    y, hf = S.ssm_forward(bp["ssm"], L.rms_norm(x, bp["ln"], cfg.norm_eps), cfg)
    return constrain_batch(x + y), (hf if collect else None)


def _rec_block(bp, x, cfg, collect=False):
    y, hf = R.rglru_forward(bp["rec"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), cfg)
    x = x + y
    x = x + L.mlp_forward(bp["mlp"], L.rms_norm(x, bp["ln2"], cfg.norm_eps), cfg)
    return constrain_batch(x), (hf if collect else None)


def _cross_block(bp, x, src, cfg, collect=False):
    h, kv = L.attention_forward(bp["cross"], L.rms_norm(x, bp["ln1"], cfg.norm_eps),
                                cfg, causal=False, kv_src=src)
    x = x + h
    x = x + L.mlp_forward(bp["mlp"], L.rms_norm(x, bp["ln2"], cfg.norm_eps), cfg)
    return constrain_batch(x), (kv if collect else None)


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, batch, cfg: ArchConfig, *, return_cache: bool = False,
            attn_impl: str = "xla", window: Optional[int] = None):
    """batch: {"tokens": (B,S) int32} + "enc_emb" (encdec) / "img_emb" (vlm).
    Returns (logits fp32 (B,S,V), aux_loss scalar, cache-or-None)."""
    if window is None:
        window = cfg.sliding_window
    x = constrain_batch(L.embed(params["embed"], batch["tokens"], cfg))
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    t = cfg.arch_type

    if t == "encdec":
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)
        enc = batch["enc_emb"].astype(x.dtype)
        enc = enc + L.sinusoidal_positions(enc.shape[1], cfg.d_model).astype(x.dtype)

        def enc_body(h, bp):
            a, _ = L.attention_forward(
                bp["attn"], L.rms_norm(h, bp["ln1"], cfg.norm_eps), cfg,
                causal=False, attn_impl=attn_impl)
            h = h + a
            h = h + L.mlp_forward(bp["mlp"], L.rms_norm(h, bp["ln2"], cfg.norm_eps), cfg)
            return h, None
        enc, _ = jax.lax.scan(_maybe_remat(enc_body, cfg), enc, params["enc"])
        enc = L.rms_norm(enc, params["enc_ln"], cfg.norm_eps)

        def dec_body(h, bp):
            a, kv = L.attention_forward(
                bp["attn"], L.rms_norm(h, bp["ln1"], cfg.norm_eps), cfg,
                attn_impl=attn_impl)
            h = h + a
            c, ckv = L.attention_forward(
                bp["cross"], L.rms_norm(h, bp["ln2"], cfg.norm_eps), cfg,
                causal=False, kv_src=enc)
            h = h + c
            h = h + L.mlp_forward(bp["mlp"], L.rms_norm(h, bp["ln3"], cfg.norm_eps), cfg)
            return h, ({"k": kv[0], "v": kv[1],
                        "ck": ckv[0], "cv": ckv[1]} if return_cache else None)
        x, dec_cache = jax.lax.scan(_maybe_remat(dec_body, cfg), x, params["blocks"])
        if return_cache:
            cache["blocks"] = dec_cache

    elif t in ("dense", "moe"):
        if t == "dense":
            def body(h, bp):
                h, kv = _dense_block(bp, h, cfg, window=window,
                                     attn_impl=attn_impl, collect=return_cache)
                return h, ({"k": kv[0], "v": kv[1]} if return_cache else None)
            x, kvs = jax.lax.scan(_maybe_remat(body, cfg), x, params["blocks"])
        else:
            def body(h, bp):
                h, a, kv = _moe_block(bp, h, cfg, window=window,
                                      attn_impl=attn_impl, collect=return_cache)
                return h, (a, {"k": kv[0], "v": kv[1]} if return_cache else None)
            x, (auxs, kvs) = jax.lax.scan(_maybe_remat(body, cfg), x, params["blocks"])
            aux = auxs.sum()
        if return_cache:
            cache["blocks"] = kvs

    elif t == "ssm":
        def body(h, bp):
            h, hf = _ssm_block(bp, h, cfg, collect=return_cache)
            return h, hf
        x, hfs = jax.lax.scan(_maybe_remat(body, cfg), x, params["blocks"])
        if return_cache:
            cache["blocks"] = hfs

    elif t == "hybrid":
        plen, n_super, n_rem = _hybrid_counts(cfg)
        lw = cfg.hybrid.local_window

        def super_body(h, sp):
            states = []
            n_rec = sp["rec"]["ln1"].shape[0]
            for i in range(n_rec):
                bp = jax.tree.map(lambda a: a[i], sp["rec"])
                h, st = _rec_block(bp, h, cfg, collect=return_cache)
                states.append(st)
            h, kv = _dense_block(sp["attn"], h, cfg, window=lw,
                                 attn_impl=attn_impl, collect=return_cache)
            out = None
            if return_cache:
                out = {"rec": jnp.stack(states), "k": kv[0], "v": kv[1]}
            return h, out
        x, sc = jax.lax.scan(_maybe_remat(super_body, cfg), x, params["super"])
        if return_cache:
            cache["super"] = sc
        if n_rem:
            rems = []
            for i in range(n_rem):
                bp = jax.tree.map(lambda a: a[i], params["rem"])
                x, st = _rec_block(bp, x, cfg, collect=return_cache)
                rems.append(st)
            if return_cache:
                cache["rem"] = jnp.stack(rems)

    elif t == "vlm":
        per, n_super, n_rem = _vlm_counts(cfg)
        img = batch["img_emb"].astype(x.dtype)

        def super_body(h, sp):
            kvs = []
            for i in range(per - 1):
                bp = jax.tree.map(lambda a: a[i], sp["self"])
                h, kv = _dense_block(bp, h, cfg, window=window,
                                     attn_impl=attn_impl, collect=return_cache)
                kvs.append(kv)
            h, ckv = _cross_block(sp["cross"], h, img, cfg, collect=return_cache)
            out = None
            if return_cache:
                out = {"k": jnp.stack([kv[0] for kv in kvs]),
                       "v": jnp.stack([kv[1] for kv in kvs]),
                       "ck": ckv[0], "cv": ckv[1]}
            return h, out
        x, sc = jax.lax.scan(_maybe_remat(super_body, cfg), x, params["super"])
        if return_cache:
            cache["super"] = sc
        if n_rem:
            for i in range(n_rem):
                bp = jax.tree.map(lambda a: a[i], params["rem"])
                x, _ = _dense_block(bp, x, cfg, window=window, attn_impl=attn_impl)
    else:
        raise ValueError(t)

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, aux, (cache if return_cache else None)


# ---------------------------------------------------------------------------
# Decode (one token, cached)
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch: int, seq_len: int,
               window: Optional[int] = None):
    """Decode-state pytree. Attention caches are (B, W, K, hd) ring buffers
    where W = min(window-or-sliding-window, seq_len)."""
    if window is None:
        window = cfg.sliding_window
    t = cfg.arch_type
    cd = cfg.dtype("compute")
    if t in ("dense", "moe"):
        one = L.init_attn_cache(batch, cfg, seq_len, window)
        return {"blocks": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(), one)}
    if t == "ssm":
        one = S.init_ssm_cache(batch, cfg)
        return {"blocks": jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(), one)}
    if t == "hybrid":
        plen, n_super, n_rem = _hybrid_counts(cfg)
        n_rec = sum(1 for x in cfg.hybrid.pattern if x == "rec")
        rec_one = R.init_rglru_cache(batch, cfg)
        attn_one = L.init_attn_cache(batch, cfg, seq_len, cfg.hybrid.local_window)
        sup = {"rec": jax.tree.map(
                   lambda a: jnp.broadcast_to(a, (n_super, n_rec) + a.shape).copy(),
                   rec_one),
               "attn": jax.tree.map(
                   lambda a: jnp.broadcast_to(a, (n_super,) + a.shape).copy(),
                   attn_one)}
        out = {"super": sup}
        if n_rem:
            out["rem"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_rem,) + a.shape).copy(), rec_one)
        return out
    if t == "vlm":
        per, n_super, n_rem = _vlm_counts(cfg)
        one = L.init_attn_cache(batch, cfg, seq_len, window)
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        img_kv = jnp.zeros((n_super, batch, cfg.num_image_tokens, kv, hd), dtype=cd)
        out = {"super": {
            "self": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_super, per - 1) + a.shape).copy(), one),
            "ck": img_kv, "cv": img_kv}}
        if n_rem:
            out["rem"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_rem,) + a.shape).copy(), one)
        return out
    if t == "encdec":
        one = L.init_attn_cache(batch, cfg, seq_len, None)
        kv, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cross = jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq, kv, hd), dtype=cd)
        return {"blocks": {
            "k": jnp.broadcast_to(one["k"], (cfg.num_layers,) + one["k"].shape).copy(),
            "v": jnp.broadcast_to(one["v"], (cfg.num_layers,) + one["v"].shape).copy(),
            "ck": cross, "cv": cross}}
    raise ValueError(t)


def decode_step(params, cache, tokens, pos, cfg: ArchConfig,
                window: Optional[int] = None):
    """tokens: (B,1) int32; pos: scalar int32. Returns (logits (B,1,V), cache)."""
    if window is None:
        window = cfg.sliding_window
    x = L.embed(params["embed"], tokens, cfg)
    t = cfg.arch_type

    if t in ("dense", "moe"):
        def body(h, xs):
            bp, c = xs
            a, nc = L.attention_decode(
                bp["attn"], L.rms_norm(h, bp["ln1"], cfg.norm_eps), c, pos, cfg,
                window=window)
            h = h + a
            h2 = L.rms_norm(h, bp["ln2"], cfg.norm_eps)
            if t == "dense":
                h = h + L.mlp_forward(bp["mlp"], h2, cfg)
            else:
                y, _ = M.moe_forward(bp["moe"], h2, cfg)
                h = h + y
            return h, nc
        x, nc = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": nc}

    elif t == "ssm":
        def body(h, xs):
            bp, c = xs
            y, nc = S.ssm_decode(bp["ssm"], L.rms_norm(h, bp["ln"], cfg.norm_eps),
                                 c, cfg)
            return h + y, nc
        x, nc = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": nc}

    elif t == "hybrid":
        plen, n_super, n_rem = _hybrid_counts(cfg)
        lw = cfg.hybrid.local_window

        def body(h, xs):
            sp, c = xs
            nrec = []
            n_rec = sp["rec"]["ln1"].shape[0]
            for i in range(n_rec):
                bp = jax.tree.map(lambda a: a[i], sp["rec"])
                ci = jax.tree.map(lambda a: a[i], c["rec"])
                y, nci = R.rglru_decode(
                    bp["rec"], L.rms_norm(h, bp["ln1"], cfg.norm_eps), ci, cfg)
                h = h + y
                h = h + L.mlp_forward(bp["mlp"],
                                      L.rms_norm(h, bp["ln2"], cfg.norm_eps), cfg)
                nrec.append(nci)
            bp = sp["attn"]
            a, nattn = L.attention_decode(
                bp["attn"], L.rms_norm(h, bp["ln1"], cfg.norm_eps), c["attn"],
                pos, cfg, window=lw)
            h = h + a
            h = h + L.mlp_forward(bp["mlp"],
                                  L.rms_norm(h, bp["ln2"], cfg.norm_eps), cfg)
            nrec = jax.tree.map(lambda *xs: jnp.stack(xs), *nrec)
            return h, {"rec": nrec, "attn": nattn}
        x, nsup = jax.lax.scan(body, x, (params["super"], cache["super"]))
        new_cache = {"super": nsup}
        if n_rem:
            nrem = []
            for i in range(n_rem):
                bp = jax.tree.map(lambda a: a[i], params["rem"])
                ci = jax.tree.map(lambda a: a[i], cache["rem"])
                y, nci = R.rglru_decode(
                    bp["rec"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), ci, cfg)
                x = x + y
                x = x + L.mlp_forward(bp["mlp"],
                                      L.rms_norm(x, bp["ln2"], cfg.norm_eps), cfg)
                nrem.append(nci)
            new_cache["rem"] = jax.tree.map(lambda *xs: jnp.stack(xs), *nrem)

    elif t == "vlm":
        per, n_super, n_rem = _vlm_counts(cfg)

        def body(h, xs):
            sp, c = xs
            nself = []
            for i in range(per - 1):
                bp = jax.tree.map(lambda a: a[i], sp["self"])
                ci = jax.tree.map(lambda a: a[i], c["self"])
                a, nci = L.attention_decode(
                    bp["attn"], L.rms_norm(h, bp["ln1"], cfg.norm_eps), ci, pos,
                    cfg, window=window)
                h = h + a
                h = h + L.mlp_forward(bp["mlp"],
                                      L.rms_norm(h, bp["ln2"], cfg.norm_eps), cfg)
                nself.append(nci)
            bp = sp["cross"]
            a, _ = L.attention_decode(
                bp["cross"], L.rms_norm(h, bp["ln1"], cfg.norm_eps), None, pos,
                cfg, kv_src_cache={"k": c["ck"], "v": c["cv"]})
            h = h + a
            h = h + L.mlp_forward(bp["mlp"],
                                  L.rms_norm(h, bp["ln2"], cfg.norm_eps), cfg)
            nself = jax.tree.map(lambda *xs: jnp.stack(xs), *nself)
            return h, {"self": nself, "ck": c["ck"], "cv": c["cv"]}
        x, nsup = jax.lax.scan(body, x, (params["super"], cache["super"]))
        new_cache = {"super": nsup}
        if n_rem:
            nrem = []
            for i in range(n_rem):
                bp = jax.tree.map(lambda a: a[i], params["rem"])
                ci = jax.tree.map(lambda a: a[i], cache["rem"])
                a, nci = L.attention_decode(
                    bp["attn"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), ci, pos,
                    cfg, window=window)
                x = x + a
                x = x + L.mlp_forward(bp["mlp"],
                                      L.rms_norm(x, bp["ln2"], cfg.norm_eps), cfg)
                nrem.append(nci)
            new_cache["rem"] = jax.tree.map(lambda *xs: jnp.stack(xs), *nrem)

    elif t == "encdec":
        x = x + L.sinusoidal_positions(1, cfg.d_model).astype(x.dtype)

        def body(h, xs):
            bp, c = xs
            a, nself = L.attention_decode(
                bp["attn"], L.rms_norm(h, bp["ln1"], cfg.norm_eps),
                {"k": c["k"], "v": c["v"]}, pos, cfg)
            h = h + a
            cc, _ = L.attention_decode(
                bp["cross"], L.rms_norm(h, bp["ln2"], cfg.norm_eps), None, pos,
                cfg, kv_src_cache={"k": c["ck"], "v": c["cv"]})
            h = h + cc
            h = h + L.mlp_forward(bp["mlp"],
                                  L.rms_norm(h, bp["ln3"], cfg.norm_eps), cfg)
            return h, {"k": nself["k"], "v": nself["v"],
                       "ck": c["ck"], "cv": c["cv"]}
        x, nc = jax.lax.scan(body, x, (params["blocks"], cache["blocks"]))
        new_cache = {"blocks": nc}
    else:
        raise ValueError(t)

    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, new_cache


def prefill(params, cache, tokens, cfg: ArchConfig,
            window: Optional[int] = None):
    """Batched prompt prefill: fill the KV/state cache for a whole (B, P)
    prompt in ONE jitted call and return the logits at the last prompt
    position (the first generation step's input).

    Internally a ``lax.scan`` of ``decode_step`` over prompt positions —
    cache-consistent for every arch family (ring buffers, SSM/RG-LRU
    states, cross-attention) with none of the per-token Python dispatch
    the old decode-the-prompt loop paid. Returns (logits (B,1,V), cache).
    """
    P = tokens.shape[1]
    if P == 1:
        return decode_step(params, cache, tokens, jnp.int32(0), cfg, window)

    def body(c, t):
        tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
        _, c = decode_step(params, c, tok, t, cfg, window)
        return c, None

    cache, _ = jax.lax.scan(body, cache, jnp.arange(P - 1, dtype=jnp.int32))
    return decode_step(params, cache, tokens[:, P - 1:P],
                       jnp.int32(P - 1), cfg, window)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(params, batch, cfg: ArchConfig, *, attn_impl: str = "xla",
            window: Optional[int] = None):
    """Next-token cross-entropy (+ MoE aux). batch needs "tokens","labels"."""
    logits, aux, _ = forward(params, batch, cfg, attn_impl=attn_impl,
                             window=window)
    logp = jax.nn.log_softmax(logits, axis=-1)
    lab = batch["labels"]
    nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    return nll.mean() + aux
