"""Paged KV cache: fixed-size pages + per-slot page tables, layered on the
dense ``(B, W, K, hd)`` ring-buffer layout from ``models.layers``.

Storage contract
----------------
The device pool is ``(L, num_pages, page_size, K, hd)`` for k and v; a
slot's logical cache is ``pages_per_slot`` pages whose ids live in its
page-table row, and gathering ``pool[table[b]]`` then reshaping yields
exactly the dense ``(W, K, hd)`` ring buffer (``W = pages_per_slot *
page_size``) the reference ``attention_decode`` reads — which is what
makes paged decode *bitwise* equal to the dense path (pinned in
``tests/test_serving.py``).

Page 0 is a reserved scratch page, never allocated: freed / never-filled
table entries point at it, so an inactive slot's masked write targets
scratch and writes back the value it just read. Duplicate scatter indices
therefore only ever carry identical payloads and the update is
order-independent — deterministic slot recycling with no retracing.

The allocator is host-side (numpy tables, a free list): pages are
allocated lazily as a slot's sequence crosses page boundaries and
returned wholesale when the request retires, so peak KV memory follows
live tokens, not ``slots * max_seq``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class PagedCacheSpec:
    """Static shape of a paged KV pool (one pool per model, all layers)."""
    num_slots: int
    page_size: int
    pages_per_slot: int
    num_layers: int
    kv_heads: int
    head_dim: int
    dtype: str = "float32"
    extra_pages: int = 0  # slack beyond slots*pages_per_slot (besides scratch)

    @property
    def seq_capacity(self) -> int:
        """W: the dense ring-buffer width a full table row gathers to."""
        return self.pages_per_slot * self.page_size

    @property
    def num_pages(self) -> int:
        """Pool size including the reserved scratch page 0."""
        return 1 + self.num_slots * self.pages_per_slot + self.extra_pages

    @classmethod
    def for_config(cls, cfg: ArchConfig, *, num_slots: int, page_size: int,
                   max_seq: int, window: Optional[int] = None,
                   extra_pages: int = 0) -> "PagedCacheSpec":
        W = min(window, max_seq) if window is not None else max_seq
        if W % page_size:
            raise ValueError(
                f"page_size={page_size} must divide the cache width W={W} "
                "(bitwise parity with the dense ring buffer needs the "
                "gathered view to be exactly (B, W, K, hd))")
        return cls(num_slots=num_slots, page_size=page_size,
                   pages_per_slot=W // page_size, num_layers=cfg.num_layers,
                   kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
                   dtype=cfg.dtype("compute").name,
                   extra_pages=extra_pages)


def init_pages(spec: PagedCacheSpec):
    """Zero-filled device pools: {"k","v"}: (L, P, page, K, hd)."""
    shape = (spec.num_layers, spec.num_pages, spec.page_size,
             spec.kv_heads, spec.head_dim)
    dt = jnp.dtype(spec.dtype)
    return {"k": jnp.zeros(shape, dtype=dt), "v": jnp.zeros(shape, dtype=dt)}


class PageAllocator:
    """Host-side page bookkeeping: free list + per-slot tables.

    Tables are plain numpy (fed to the jitted step as a changing-value,
    fixed-shape operand — no retrace). Page 0 is never handed out.
    """

    def __init__(self, spec: PagedCacheSpec):
        self.spec = spec
        self._free = list(range(spec.num_pages - 1, 0, -1))  # pop() -> low ids
        self.tables = np.zeros((spec.num_slots, spec.pages_per_slot),
                               dtype=np.int32)
        self._owned = [0] * spec.num_slots  # pages allocated per slot

    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.spec.num_pages - 1 - len(self._free)

    def can_fit(self, length: int) -> bool:
        need = -(-min(length, self.spec.seq_capacity) // self.spec.page_size)
        return len(self._free) >= need

    def ensure(self, slot: int, length: int) -> None:
        """Grow slot's table so it covers ``length`` cache positions.

        Ring slots wrap at seq_capacity, so a slot never needs more than
        pages_per_slot pages. Raises if the pool is exhausted — admission
        control (``can_fit``) is the caller's job.
        """
        need = -(-min(length, self.spec.seq_capacity) // self.spec.page_size)
        while self._owned[slot] < need:
            if not self._free:
                raise RuntimeError(
                    f"paged KV pool exhausted ({self.spec.num_pages} pages, "
                    f"slot {slot} needs page {self._owned[slot]})")
            self.tables[slot, self._owned[slot]] = self._free.pop()
            self._owned[slot] += 1

    def release(self, slot: int) -> None:
        """Retire a request: return its pages, point the row at scratch."""
        for i in range(self._owned[slot]):
            self._free.append(int(self.tables[slot, i]))
        self.tables[slot, :] = 0
        self._owned[slot] = 0
