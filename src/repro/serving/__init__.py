"""Serving counterpart of the training Engine: continuous batching over a
slot-recycled paged KV cache, trace-driven arrivals, and a latency-SLO
planner (``repro.cluster.serving``).

Layout mirrors the training side: ``paged_cache`` owns the storage
(page pools + per-slot page tables + host allocator), ``decode`` owns the
math (per-request-position decode step, bit-matching the dense ring
buffer in ``models.layers``), ``engine`` owns the loop (admission,
prefill, retire, obs instrumentation).
"""
from repro.serving.paged_cache import (PagedCacheSpec, PageAllocator,
                                       init_pages)
from repro.serving.decode import (ATTN_IMPLS, paged_attention_decode,
                                  paged_decode_step)
from repro.serving.engine import (Request, ServeReport, ContinuousServer,
                                  poisson_trace, sample_requests,
                                  static_serve_trace)

__all__ = [
    "PagedCacheSpec", "PageAllocator", "init_pages",
    "ATTN_IMPLS", "paged_attention_decode", "paged_decode_step",
    "Request", "ServeReport", "ContinuousServer",
    "poisson_trace", "sample_requests", "static_serve_trace",
]
