"""Continuous-batching serving loop over the paged KV cache.

The serving counterpart of ``engine.Engine``: one compiled decode step at
a fixed batch width (= slots) serves a changing request population —
requests are admitted into free slots as they arrive (queue), prefilled,
decoded one token per step, and retired the step their generation
completes, returning their pages to the pool. No call ever retraces on
population change: slot membership is data (page tables, position
vector, active mask), not shape.

Arrivals are an ``exec.trace.EventTrace`` (it is exactly an
arrival/commit log): ``commit_time`` carries arrival times and
``read_version[t] = t`` (staleness 0 — nothing is read asynchronously).
``poisson_trace`` draws reproducible Poisson arrivals; any saved trace
replays the same offered load.

Time is the repo's one clock (``engine.timing.monotonic``). The loop
runs on measured wall-clock, with one virtualization: when every slot is
empty and the next arrival is in the future, the clock skips forward
instead of sleeping, so a 50-request trace benches in compute time while
queueing delays stay real. Per-request output is independent of batch
composition (pinned in tests), so admission timing never changes tokens.

Prefill modes:
- ``"scan"`` (default): a jitted scan of the paged decode step over
  prompt positions, bucketed by prompt length — bitwise-identical cache
  and first token to the sequential reference (``T.prefill`` is the same
  scan over a dense cache).
- ``"parallel"``: one ``T.forward`` pass over the whole prompt
  (``attn_impl="pallas"`` routes it through the flash kernel), KV rows
  scattered into the slot's pages. One call instead of P steps — the
  prefill hot path — numerically allclose to scan, not bitwise
  (parallel vs stepwise attention reduction order). Full-window caches
  only: a ring-wrapped scatter would need last-writer selection.

Decode cost tracks live context, not pool capacity:
- ``attn_impl="pallas"`` routes decode (and the scan-prefill inner
  step) through the in-kernel paged-attention walk
  (``repro.kernels.paged_attention``) — no dense gather at all, per-row
  positions bound the page walk, sliding windows included.
- the XLA path gathers only up to the batch's live high-water page
  count, bucketed to a power-of-two page ladder (``gather_mode=
  "bucket"``) so changing populations reuse compiled steps;
  ``gather_mode="full"`` pins the full-capacity gather — the bitwise
  baseline arm.
- ``attn_impl="pallas_gather"`` (the legacy flash-over-a-copy hot path)
  cannot represent a wrapped ring: under a sliding window it falls back
  to the XLA path, and the server says so — ``warnings.warn`` +
  ``registry.note`` — instead of silently switching.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.engine.timing import monotonic
from repro.exec.trace import EventTrace
from repro.models import transformer as T
from repro.obs import spans
from repro.obs.metrics import MetricRegistry
from repro.serving.decode import ATTN_IMPLS, paged_decode_step
from repro.serving.paged_cache import PagedCacheSpec, PageAllocator, init_pages


# ---------------------------------------------------------------------------
# Offered load: traces and request sampling
# ---------------------------------------------------------------------------

def poisson_trace(rate: float, n: int, seed: int = 0) -> EventTrace:
    """Reproducible Poisson arrivals at ``rate`` req/s as an EventTrace
    (commit_time = arrival times, staleness 0)."""
    if rate <= 0:
        raise ValueError("rate must be positive")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    t = np.arange(n, dtype=np.int64)
    return EventTrace(num_groups=1, group=np.zeros(n, np.int32),
                      read_version=t, commit_time=arrivals)


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request: prompt tokens + generation budget."""
    rid: int
    arrival: float
    prompt: np.ndarray          # (P,) int32
    gen: int


def sample_requests(trace: EventTrace, cfg: ArchConfig, *,
                    prompt_range=(8, 32), gen_range=(4, 32),
                    seed: int = 0) -> List[Request]:
    """One request per trace event. Prompt tokens and lengths come from an
    RNG keyed by (seed, rid) alone, so request rid is byte-identical across
    traces/rates — the solo bit-match tests and the continuous-vs-static
    bench replay the exact same work."""
    out = []
    for rid, arrival in enumerate(np.asarray(trace.commit_time)):
        rng = np.random.default_rng((seed, rid))
        plen = int(rng.integers(prompt_range[0], prompt_range[1] + 1))
        gen = int(rng.integers(gen_range[0], gen_range[1] + 1))
        prompt = rng.integers(cfg.vocab_size, size=plen).astype(np.int32)
        out.append(Request(rid=rid, arrival=float(arrival),
                           prompt=prompt, gen=gen))
    return out


# ---------------------------------------------------------------------------
# Report
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeReport:
    """Per-request accounting for one serving run (times in seconds on the
    run's virtual clock; latency = finish - arrival)."""
    mode: str
    rids: np.ndarray
    arrivals: np.ndarray
    queue_waits: np.ndarray
    latencies: np.ndarray
    gen_counts: np.ndarray
    tokens: Dict[int, np.ndarray]
    makespan: float
    occupancy_mean: float

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.latencies, q))

    @property
    def total_tokens(self) -> int:
        return int(self.gen_counts.sum())

    @property
    def throughput(self) -> float:
        """Generated tokens per second of makespan."""
        return self.total_tokens / max(self.makespan, 1e-12)

    def goodput(self, slo_s: float) -> float:
        """Tokens/s counting only requests whose latency met the SLO —
        the paper's HE x SE product transposed to serving: raw throughput
        discounted by the fraction of it that was statistically useful
        (delivered within the latency target)."""
        ok = self.latencies <= slo_s
        return float(self.gen_counts[ok].sum()) / max(self.makespan, 1e-12)


def _bucket(n: int, cap: Optional[int] = None) -> int:
    b = 1
    while b < n:
        b <<= 1
    return min(b, cap) if cap is not None else b


# ---------------------------------------------------------------------------
# Continuous-batching server
# ---------------------------------------------------------------------------

class ContinuousServer:
    """Slot-recycled continuous batching (module docstring)."""

    def __init__(self, cfg: ArchConfig, params=None, *, slots: int = 8,
                 page_size: int = 16, max_seq: int = 256,
                 window: Optional[int] = "config", attn_impl: str = "xla",
                 prefill_mode: str = "scan", gather_mode: str = "bucket",
                 seed: int = 0,
                 registry: Optional[MetricRegistry] = None,
                 extra_pages: int = 0):
        if window == "config":
            window = cfg.sliding_window
        if prefill_mode not in ("scan", "parallel"):
            raise ValueError(f"unknown prefill_mode {prefill_mode!r}")
        if prefill_mode == "parallel" and window is not None:
            raise ValueError("parallel prefill needs a full (non-ring) cache")
        if attn_impl not in ATTN_IMPLS:
            raise ValueError(f"attn_impl must be one of {ATTN_IMPLS}, "
                             f"not {attn_impl!r}")
        if gather_mode not in ("bucket", "full"):
            raise ValueError(f"unknown gather_mode {gather_mode!r}")
        self.cfg = cfg
        self.window = window
        self.attn_impl = attn_impl
        self.prefill_mode = prefill_mode
        self.gather_mode = gather_mode
        self.params = params if params is not None else T.init_params(
            jax.random.PRNGKey(seed), cfg)
        self.spec = PagedCacheSpec.for_config(
            cfg, num_slots=slots, page_size=page_size, max_seq=max_seq,
            window=window, extra_pages=extra_pages)
        self.alloc = PageAllocator(self.spec)
        self.pages = init_pages(self.spec)
        self.registry = registry if registry is not None else MetricRegistry()

        # the one remaining impl fallback, made loud: flash-over-a-copy
        # cannot express a wrapped ring, so sliding windows run the XLA
        # masked path — warn once and pin it in the metric stream's notes
        self._fallback_note: Optional[str] = None
        if attn_impl == "pallas_gather" and window is not None:
            self._fallback_note = (
                "attn_impl='pallas_gather' cannot run a sliding-window "
                f"(window={window}) ring cache: slot order != position "
                "order after wrap breaks the flash kernel's positional "
                "mask; decode falls back to the masked XLA path "
                "(attn_impl='pallas' walks the page table in-kernel and "
                "has no such fallback)")
            warnings.warn(self._fallback_note, stacklevel=2)
            self.registry.note(self._fallback_note)

        S = self.spec.num_slots
        win, impl = self.window, self.attn_impl

        def _step(params, pages, table, tokens, pos, active, *,
                  gather_pages: Optional[int] = None):
            logits, pages = paged_decode_step(
                params, pages, table, tokens, pos, active, cfg,
                window=win, attn_impl=impl, gather_pages=gather_pages)
            return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), pages

        self._step_impl = _step
        self._step_cache: Dict[Optional[int], Callable] = {}
        self._prefill_cache: Dict[tuple, Callable] = {}

        def _scan_prefill(params, pages, table, prompts, plens, admit, *,
                          gather_pages: Optional[int] = None):
            Pb = prompts.shape[1]

            def body(pg, t):
                tok = jax.lax.dynamic_slice_in_dim(prompts, t, 1, axis=1)
                act = admit & (t < plens)
                logits, pg = paged_decode_step(
                    params, pg, table, tok, jnp.full((S,), t, jnp.int32),
                    act, cfg, window=win, attn_impl=impl,
                    gather_pages=gather_pages)
                return pg, jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)

            pages, toks = jax.lax.scan(body, pages,
                                       jnp.arange(Pb, dtype=jnp.int32))
            return pages, toks                       # toks: (Pb, S)

        def _parallel_prefill(params, pages, table, prompts, plens, admit, *,
                              gather_pages: Optional[int] = None):
            B, Pb = prompts.shape
            page = self.spec.page_size
            logits, _, cache = T.forward(params, {"tokens": prompts}, cfg,
                                         return_cache=True, attn_impl=impl,
                                         window=win)
            tpos = jnp.arange(Pb)[None, :]                     # (1, Pb)
            act = admit[:, None] & (tpos < plens[:, None])     # (B, Pb)
            pidx = jnp.broadcast_to(tpos // page, (B, Pb))
            pid = jnp.take_along_axis(table, pidx, axis=1)     # (B, Pb)
            inpg = jnp.broadcast_to(tpos % page, (B, Pb))
            actx = act[None, :, :, None, None]
            new_pages = {}
            for name in ("k", "v"):
                pool = pages[name]                             # (L,P,pg,K,hd)
                rows = cache["blocks"][name].astype(pool.dtype)
                old = pool[:, pid, inpg]                       # (L,B,Pb,K,hd)
                new_pages[name] = pool.at[:, pid, inpg].set(
                    jnp.where(actx, rows, old))
            toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (B, Pb)
            return new_pages, toks.T                           # (Pb, B)

        self._prefill_impl = (_scan_prefill if prefill_mode == "scan"
                              else _parallel_prefill)

    def reset(self, registry: Optional[MetricRegistry] = None) -> None:
        """Fresh pool/allocator (and optionally a fresh metric registry)
        while keeping every compiled step/prefill bucket — so a measured
        run can follow a warmup run without paying compilation twice."""
        self.alloc = PageAllocator(self.spec)
        self.pages = init_pages(self.spec)
        if registry is not None:
            self.registry = registry
            if self._fallback_note is not None:
                self.registry.note(self._fallback_note)

    def _uses_gather(self) -> bool:
        """Does the decode step materialize a dense gathered view at all?
        ``"pallas"`` walks the table in-kernel; everything else gathers."""
        return self.attn_impl != "pallas"

    def _step_fn(self, gather_pages: Optional[int]) -> Callable:
        """Compiled decode step for one static gather width (None = full
        capacity — the bitwise baseline). One entry per ladder rung."""
        fn = self._step_cache.get(gather_pages)
        if fn is None:
            fn = jax.jit(
                functools.partial(self._step_impl, gather_pages=gather_pages),
                donate_argnums=(1,))
            self._step_cache[gather_pages] = fn
        return fn

    def _gather_bucket(self, slot_pos: np.ndarray,
                       active: np.ndarray) -> Optional[int]:
        """The batch's live high-water page count, rounded up the
        power-of-two ladder. Active rows only: retired slots keep stale
        positions that must not widen (or overrun) the gather. None means
        full width — pallas (no gather), ``gather_mode="full"``, or a
        batch already at capacity."""
        if self.gather_mode == "full" or not self._uses_gather():
            return None
        if not active.any():
            return None
        live = min(int(slot_pos[active].max()) + 1, self.spec.seq_capacity)
        gp = _bucket(-(-live // self.spec.page_size), self.spec.pages_per_slot)
        return None if gp >= self.spec.pages_per_slot else gp

    def _prefill_gather(self, Pb: int) -> Optional[int]:
        """Gather width for a scan prefill over a ``Pb``-bucket prompt:
        positions stay < Pb, and non-admitted rows' outputs are discarded,
        so the view only needs the prompt's own pages."""
        if self.gather_mode == "full" or not self._uses_gather():
            return None
        live = min(Pb, self.spec.seq_capacity)
        gp = _bucket(-(-live // self.spec.page_size), self.spec.pages_per_slot)
        return None if gp >= self.spec.pages_per_slot else gp

    def _prefill_fn(self, Pb: int):
        key = (Pb, self._prefill_gather(Pb))
        fn = self._prefill_cache.get(key)
        if fn is None:
            fn = jax.jit(
                functools.partial(self._prefill_impl, gather_pages=key[1]),
                donate_argnums=(1,))
            self._prefill_cache[key] = fn
        return fn

    def _gather_ladder(self) -> List[Optional[int]]:
        """Every gather width a run can request: the full-capacity arm
        plus (in bucket mode) each power-of-two rung below capacity."""
        ladder: List[Optional[int]] = [None]
        if self.gather_mode == "bucket" and self._uses_gather():
            gp = 1
            while gp < self.spec.pages_per_slot:
                ladder.append(gp)
                gp <<= 1
        return ladder

    def warmup(self, prompt_lens: Sequence[int] = ()) -> None:
        """Compile the decode-step gather ladder and the prefill buckets
        for the given prompt lengths without touching any state: an
        all-inactive call writes back exactly what it reads."""
        S = self.spec.num_slots
        table = jnp.asarray(self.alloc.tables)
        off = jnp.zeros((S,), jnp.int32)
        inact = jnp.zeros((S,), bool)
        for gp in self._gather_ladder():
            tok, self.pages = self._step_fn(gp)(
                self.params, self.pages, table,
                jnp.zeros((S, 1), jnp.int32), off, inact)
            jax.block_until_ready(tok)
        cap = self.spec.seq_capacity if self.window is None else None
        for p in sorted({_bucket(int(p), cap) for p in prompt_lens}):
            fn = self._prefill_fn(p)
            self.pages, toks = fn(self.params, self.pages, table,
                                  jnp.zeros((S, p), jnp.int32), off, inact)
            jax.block_until_ready(toks)

    def run(self, requests: Sequence[Request]) -> ServeReport:
        """Serve every request; returns per-request accounting."""
        cfg, spec, alloc = self.cfg, self.spec, self.alloc
        S = spec.num_slots
        cap = spec.seq_capacity
        reg = self.registry
        queue_wait = reg.series("serving.queue_wait_s")
        prefill_s = reg.series("serving.prefill_s")
        decode_s = reg.series("serving.decode_s")
        step_s = reg.series("serving.decode_step_s")
        latency_s = reg.series("serving.latency_s")
        occupancy = reg.series("serving.occupancy")
        occ_gauge = reg.gauge("serving.batch_occupancy")
        pages_gauge = reg.gauge("serving.pages_in_use")
        done_ctr = reg.counter("serving.requests_completed")
        tok_ctr = reg.counter("serving.tokens_generated")

        reqs = sorted(requests, key=lambda r: r.arrival)
        if self.window is None:
            for r in reqs:
                if len(r.prompt) + r.gen > cap:
                    raise ValueError(
                        f"request {r.rid}: prompt {len(r.prompt)} + gen "
                        f"{r.gen} exceeds cache capacity {cap}")

        slot_req: List[Optional[Request]] = [None] * S
        slot_pos = np.zeros(S, np.int32)       # next decode position
        slot_tok = np.zeros(S, np.int32)       # next input token
        slot_left = np.zeros(S, np.int64)      # decode steps remaining
        slot_pf_end = np.zeros(S, np.float64)  # prefill end (virtual clock)
        out_tokens: Dict[int, List[int]] = {}
        finished: Dict[int, dict] = {}

        t0 = monotonic()
        voff = 0.0
        now = lambda: monotonic() - t0 + voff
        qi = 0
        n_active = 0
        steps = 0
        occ_samples: List[int] = []

        def retire(s: int, tnow: float) -> None:
            nonlocal n_active
            r = slot_req[s]
            lat = tnow - r.arrival
            finished[r.rid] = {
                "arrival": r.arrival, "latency": lat,
                "queue_wait": finished[r.rid]["queue_wait"],
                "gen": len(out_tokens[r.rid])}
            latency_s.append(lat, step=r.rid)
            decode_s.append(tnow - slot_pf_end[s], step=r.rid)
            done_ctr.inc()
            alloc.release(s)
            slot_req[s] = None
            n_active -= 1

        while qi < len(reqs) or n_active:
            tnow = now()
            if (n_active == 0 and qi < len(reqs)
                    and reqs[qi].arrival > tnow):
                voff += reqs[qi].arrival - tnow    # idle: skip, don't sleep
                tnow = now()

            # -- admission: fill free slots from the arrived queue --------
            admits: List[int] = []
            for s in range(S):
                if qi >= len(reqs) or slot_req[s] is not None:
                    continue
                r = reqs[qi]
                need = min(len(r.prompt), cap)
                if r.arrival > tnow or not alloc.can_fit(need):
                    if (n_active == 0 and not admits
                            and r.arrival <= tnow):
                        raise RuntimeError(
                            f"request {r.rid} cannot fit an empty pool")
                    break
                alloc.ensure(s, need)
                slot_req[s] = r
                slot_pos[s] = 0
                slot_left[s] = r.gen
                out_tokens[r.rid] = []
                finished[r.rid] = {"queue_wait": tnow - r.arrival}
                queue_wait.append(tnow - r.arrival, step=r.rid)
                admits.append(s)
                qi += 1
                n_active += 1

            # -- prefill the admitted slots (one bucketed jitted call) ----
            if admits:
                plens = np.array([len(slot_req[s].prompt) if slot_req[s]
                                  else 0 for s in range(S)], np.int32)
                pmax = max(len(slot_req[s].prompt) for s in admits)
                Pb = _bucket(pmax, cap if self.window is None else None)
                prompts = np.zeros((S, Pb), np.int32)
                admit = np.zeros(S, bool)
                for s in admits:
                    r = slot_req[s]
                    prompts[s, :len(r.prompt)] = r.prompt[:Pb]
                    admit[s] = True
                tpf = now()
                with spans.span("serve.prefill", lanes=len(admits),
                                bucket=Pb):
                    fn = self._prefill_fn(Pb)
                    self.pages, toks = fn(
                        self.params, self.pages, jnp.asarray(alloc.tables),
                        jnp.asarray(prompts), jnp.asarray(plens),
                        jnp.asarray(admit))
                    toks = np.asarray(toks)        # (Pb, S); sync
                tnow = now()
                for s in admits:
                    r = slot_req[s]
                    prefill_s.append(tnow - tpf, step=r.rid)
                    slot_pf_end[s] = tnow
                    first = int(toks[len(r.prompt) - 1, s])
                    out_tokens[r.rid].append(first)
                    tok_ctr.inc()
                    slot_tok[s] = first
                    slot_pos[s] = len(r.prompt)
                    slot_left[s] = r.gen - 1
                    if slot_left[s] == 0:
                        retire(s, tnow)

            if n_active == 0:
                continue

            # -- one continuous decode step over every live slot ----------
            active = np.array([r is not None for r in slot_req])
            for s in np.nonzero(active)[0]:
                alloc.ensure(int(s), int(slot_pos[s]) + 1)
            occ_samples.append(int(active.sum()))
            occupancy.append(int(active.sum()), step=steps)
            occ_gauge.set(int(active.sum()))
            pages_gauge.set(alloc.pages_in_use)
            gp = self._gather_bucket(slot_pos, active)
            tstep = now()
            with spans.span("serve.decode_step", occupancy=int(active.sum()),
                            gather=(gp if gp is not None
                                    else spec.pages_per_slot)):
                tok, self.pages = self._step_fn(gp)(
                    self.params, self.pages, jnp.asarray(alloc.tables),
                    jnp.asarray(slot_tok[:, None]), jnp.asarray(slot_pos),
                    jnp.asarray(active))
                tok = np.asarray(tok)              # sync
            tnow = now()
            step_s.append(tnow - tstep, step=steps)
            steps += 1
            for s in np.nonzero(active)[0]:
                r = slot_req[s]
                out_tokens[r.rid].append(int(tok[s]))
                tok_ctr.inc()
                slot_tok[s] = int(tok[s])
                slot_pos[s] += 1
                slot_left[s] -= 1
                if slot_left[s] == 0:
                    retire(int(s), tnow)

        rids = np.array(sorted(finished), np.int64)
        occ = np.array(occ_samples) if occ_samples else np.zeros(1)
        return ServeReport(
            mode="continuous",
            rids=rids,
            arrivals=np.array([finished[r]["arrival"] for r in rids]),
            queue_waits=np.array([finished[r]["queue_wait"] for r in rids]),
            latencies=np.array([finished[r]["latency"] for r in rids]),
            gen_counts=np.array([finished[r]["gen"] for r in rids]),
            tokens={r: np.array(out_tokens[r], np.int32) for r in rids},
            makespan=now(),
            occupancy_mean=float(occ.mean()))


# ---------------------------------------------------------------------------
# Static-batch baseline on the same trace
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _static_fns(cfg: ArchConfig, window):
    """Jitted prefill/decode shared across calls (ArchConfig is a frozen
    dataclass, hence hashable) so back-to-back trace runs — warmup then
    measured — reuse compiled code like the continuous server does."""
    pf = jax.jit(lambda p, c, t: T.prefill(p, c, t, cfg, window))
    dec = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg,
                                                     window))
    return pf, dec


def static_serve_trace(cfg: ArchConfig, requests: Sequence[Request], *,
                       batch: int = 8, params=None, seed: int = 0,
                       window: Optional[int] = "config",
                       registry: Optional[MetricRegistry] = None
                       ) -> ServeReport:
    """The pre-continuous ``serve()`` flow run against a trace: requests
    are chunked into arrival-order batches; each batch waits for its last
    member, prefills padded prompts in one call, then decodes to the
    *longest* generation in the batch — no slot recycles early, every
    member's latency is the batch's end. The honest baseline the
    continuous server's goodput gate compares against."""
    if window == "config":
        window = cfg.sliding_window
    if params is None:
        params = T.init_params(jax.random.PRNGKey(seed), cfg)
    reg = registry if registry is not None else MetricRegistry()
    prefill_s = reg.series("serving.prefill_s")
    step_s = reg.series("serving.decode_step_s")
    latency_s = reg.series("serving.latency_s")

    pf, dec = _static_fns(cfg, window)
    reqs = sorted(requests, key=lambda r: r.arrival)
    groups = [reqs[i:i + batch] for i in range(0, len(reqs), batch)]

    finished: Dict[int, dict] = {}
    tokens: Dict[int, np.ndarray] = {}
    t0 = monotonic()
    voff = 0.0
    now = lambda: monotonic() - t0 + voff
    occ_num = 0.0
    occ_time = 0.0

    for grp in groups:
        last_arrival = max(r.arrival for r in grp)
        tnow = now()
        if last_arrival > tnow:                    # wait to fill the batch
            voff += last_arrival - tnow
            tnow = now()
        start = tnow
        pmax = _bucket(max(len(r.prompt) for r in grp))
        gmax = max(r.gen for r in grp)
        prompts = np.zeros((batch, pmax), np.int32)
        for i in range(batch):
            r = grp[min(i, len(grp) - 1)]          # pad lanes: repeat last
            prompts[i, :len(r.prompt)] = r.prompt
        total_cap = pmax + _bucket(gmax)     # bucket: bounded retraces
        cache = T.init_cache(cfg, batch, total_cap, window)
        tpf = now()
        logits, cache = jax.block_until_ready(
            pf(params, cache, jnp.asarray(prompts)))
        prefill_s.append(now() - tpf)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        outs = [np.asarray(tok)[:, 0]]
        for t in range(pmax, pmax + gmax - 1):
            ts = now()
            logits, cache = dec(params, cache, tok, jnp.int32(t))
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
            tok = tok.astype(jnp.int32)
            outs.append(np.asarray(tok)[:, 0])     # sync
            step_s.append(now() - ts)
        end = now()
        occ_num += len(grp) * (end - start)
        occ_time += end - start
        allt = np.stack(outs, axis=1)              # (batch, gmax)
        for i, r in enumerate(grp):
            finished[r.rid] = {"arrival": r.arrival,
                               "queue_wait": start - r.arrival,
                               "latency": end - r.arrival,
                               "gen": r.gen}
            latency_s.append(end - r.arrival, step=r.rid)
            tokens[r.rid] = allt[i, :r.gen].astype(np.int32)

    rids = np.array(sorted(finished), np.int64)
    makespan = now()
    return ServeReport(
        mode="static",
        rids=rids,
        arrivals=np.array([finished[r]["arrival"] for r in rids]),
        queue_waits=np.array([finished[r]["queue_wait"] for r in rids]),
        latencies=np.array([finished[r]["latency"] for r in rids]),
        gen_counts=np.array([finished[r]["gen"] for r in rids]),
        tokens=tokens,
        makespan=makespan,
        occupancy_mean=occ_num / occ_time / batch if occ_time else 0.0)
