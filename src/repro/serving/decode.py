"""Paged decode: ``models.layers.attention_decode`` generalized to a
per-request position vector over a page-table-indirected cache.

Bitwise contract (pinned in ``tests/test_serving.py``): gathering a
slot's pages yields exactly the dense ``(B, W, K, hd)`` ring buffer, the
validity mask is the reference mask evaluated per batch row, and every
einsum/softmax runs the same shapes in the same order — so logits from
``paged_decode_step`` bit-match ``models.transformer.decode_step`` on the
dense cache whenever the per-row positions agree. Masked (out-of-range /
never-written / scratch-backed) cache entries cannot leak: their scores
sit at ``-1e30`` so ``exp`` underflows to exactly ``0.0`` in fp32 before
the value gather.

Writes are recycle-safe by construction: gather the old page entry,
``where(active, new, old)``, scatter back. Inactive slots' tables point
at the reserved scratch page 0, so colliding scatter indices always carry
identical payloads and the step stays deterministic as requests join and
leave the batch — one compiled step, any population.

Attention implementations (``attn_impl``):

- ``"pallas"`` — the in-kernel paged flash-decode
  (``repro.kernels.paged_attention``): the K/V BlockSpec index maps walk
  the page table inside the kernel, pages are consumed in place with no
  dense copy, per-row ``pos`` bounds the live page walk, and a
  ring-aware mask covers sliding windows — no fallback.
- ``"xla"`` — the masked dense-gather reference. ``gather_pages``
  (static) narrows the gather to the batch's live high-water page count:
  the view becomes the FIRST ``gather_pages`` ring slots and the mask its
  matching columns, so bandwidth follows live context even without
  Pallas. ``gather_pages=None`` (or ``= max_pages``) is the full-width
  bitwise baseline arm; narrowed widths re-tile XLA's reductions, so
  cross-width equality is token-level, like any batch-width change.
- ``"pallas_gather"`` — the legacy hot path kept as a bench arm: the
  ``flash_attention`` kernel over the full gathered copy
  (``q_offsets=pos``). Flash-on-a-copy requires a full (non-ring) cache:
  under a sliding window the ring wraps and slot order no longer equals
  position order, so this arm falls back to the XLA masked path — the
  server surfaces that fallback (warning + obs note) instead of hiding
  it.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.paged_attention.ref import valid_mask as _valid_mask
from repro.models import layers as L
from repro.models import moe as M

ATTN_IMPLS = ("xla", "pallas", "pallas_gather")


def paged_attention_decode(p, x, k_pages, v_pages, table, pos, active,
                           cfg: ArchConfig, *, window: Optional[int] = None,
                           attn_impl: str = "xla",
                           gather_pages: Optional[int] = None):
    """One layer's decode over the paged pool.

    x: (B,1,D) hidden; k_pages/v_pages: (P, page, K, hd) this layer's pool;
    table: (B, max_pages) int32 page ids (0 = scratch); pos: (B,) int32
    absolute position per slot; active: (B,) bool live-request mask.
    ``gather_pages`` (static, XLA path only): gather just the first
    ``gather_pages`` table columns — must cover every live row's pages
    (the server's bucket ladder guarantees it).
    Returns (out (B,1,D), (k_pages, v_pages)).
    """
    cd = cfg.dtype("compute")
    B = x.shape[0]
    _, page, K, hd = k_pages.shape
    max_pages = table.shape[1]
    W = max_pages * page

    q, k, v = L._project_qkv(p, x, None, cfg)
    posb = pos[:, None].astype(jnp.int32)            # (B, 1)
    q = L.rope(q, posb, cfg.rope_theta)
    k = L.rope(k, posb, cfg.rope_theta)

    slot = pos % W if window is not None else pos
    page_idx = slot // page
    in_page = slot % page
    pid = jnp.take_along_axis(table, page_idx[:, None], axis=1)[:, 0]  # (B,)

    kn = k[:, 0].astype(k_pages.dtype)               # (B, K, hd)
    vn = v[:, 0].astype(v_pages.dtype)
    act = active[:, None, None]
    oldk = k_pages[pid, in_page]
    oldv = v_pages[pid, in_page]
    k_pages = k_pages.at[pid, in_page].set(jnp.where(act, kn, oldk))
    v_pages = v_pages.at[pid, in_page].set(jnp.where(act, vn, oldv))

    if attn_impl == "pallas":
        from repro.kernels.paged_attention import ops as pa_ops
        out = pa_ops.paged_attention(q, k_pages, v_pages, table, pos,
                                     window=window)
    else:
        gp = max_pages if gather_pages is None else min(gather_pages,
                                                        max_pages)
        tb = table if gp == max_pages else table[:, :gp]
        Wb = gp * page
        ck = k_pages[tb].reshape(B, Wb, K, hd)       # the dense ring view
        cv = v_pages[tb].reshape(B, Wb, K, hd)
        if attn_impl == "pallas_gather" and window is None:
            from repro.kernels.flash_attention import ops as fa_ops
            out = fa_ops.flash_attention(q, ck.astype(cd), cv.astype(cd),
                                         causal=True, q_offsets=pos)
        else:
            # the mask is the full-ring reference evaluated per row, cut
            # to the gathered columns (the first Wb ring slots)
            valid = _valid_mask(pos, W, window)[:, :Wb]
            scores = L._grouped_scores(q, ck.astype(cd)).astype(jnp.float32)
            scores = scores + jnp.where(valid, 0.0,
                                        -1e30)[:, None, None, None, :]
            w = jax.nn.softmax(scores, axis=-1).astype(cd)
            out = L._apply_scores(w, cv.astype(cd))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return y, (k_pages, v_pages)


def paged_decode_step(params, pages, table, tokens, pos, active,
                      cfg: ArchConfig, *, window: Optional[int] = None,
                      attn_impl: str = "xla",
                      gather_pages: Optional[int] = None):
    """One continuous-batching decode step for dense/moe stacks.

    pages: {"k","v"}: (L, P, page, K, hd); table: (B, max_pages) shared by
    all layers; tokens: (B,1) int32; pos: (B,) int32; active: (B,) bool.
    Returns (logits (B,1,V) fp32, new pages). Mirrors
    ``transformer.decode_step``'s layer scan so the math bit-matches.
    """
    if window is None:
        window = cfg.sliding_window
    if attn_impl not in ATTN_IMPLS:
        raise ValueError(f"attn_impl must be one of {ATTN_IMPLS}, "
                         f"not {attn_impl!r}")
    t = cfg.arch_type
    if t not in ("dense", "moe"):
        raise ValueError(f"paged decode supports dense/moe, not {t!r}")
    x = L.embed(params["embed"], tokens, cfg)

    def body(h, xs):
        bp, kp, vp = xs
        a, (nkp, nvp) = paged_attention_decode(
            bp["attn"], L.rms_norm(h, bp["ln1"], cfg.norm_eps), kp, vp,
            table, pos, active, cfg, window=window, attn_impl=attn_impl,
            gather_pages=gather_pages)
        h = h + a
        h2 = L.rms_norm(h, bp["ln2"], cfg.norm_eps)
        if t == "dense":
            h = h + L.mlp_forward(bp["mlp"], h2, cfg)
        else:
            y, _ = M.moe_forward(bp["moe"], h2, cfg)
            h = h + y
        return h, (nkp, nvp)

    x, (nk, nv) = jax.lax.scan(body, x, (params["blocks"],
                                         pages["k"], pages["v"]))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"k": nk, "v": nv}
