"""Paged decode: ``models.layers.attention_decode`` generalized to a
per-request position vector over a page-table-indirected cache.

Bitwise contract (pinned in ``tests/test_serving.py``): gathering a
slot's pages yields exactly the dense ``(B, W, K, hd)`` ring buffer, the
validity mask is the reference mask evaluated per batch row, and every
einsum/softmax runs the same shapes in the same order — so logits from
``paged_decode_step`` bit-match ``models.transformer.decode_step`` on the
dense cache whenever the per-row positions agree. Masked (out-of-range /
never-written / scratch-backed) cache entries cannot leak: their scores
sit at ``-1e30`` so ``exp`` underflows to exactly ``0.0`` in fp32 before
the value gather.

Writes are recycle-safe by construction: gather the old page entry,
``where(active, new, old)``, scatter back. Inactive slots' tables point
at the reserved scratch page 0, so colliding scatter indices always carry
identical payloads and the step stays deterministic as requests join and
leave the batch — one compiled step, any population.

``attn_impl="pallas"`` routes the score/value loop through the
``flash_attention`` kernel with ``q_offsets=pos`` (each batch row's
single query at its own absolute position). Flash decode requires a
full (non-ring) cache: under a sliding window the ring wraps and slot
order no longer equals position order, which the kernel's positional
mask assumes — the XLA masked path stays the sliding-window fallback.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as M


def _valid_mask(pos: jax.Array, W: int, window: Optional[int]) -> jax.Array:
    """Per-row ring validity, (B, W) bool — the reference mask from
    ``attention_decode`` with ``pos`` promoted to a vector."""
    slots = jnp.arange(W)[None, :]
    posb = pos[:, None]
    if window is not None:
        base = posb - (posb % W)
        abs_pos = jnp.where(slots <= (posb % W), base + slots,
                            base - W + slots)
    else:
        abs_pos = jnp.broadcast_to(slots, (pos.shape[0], W))
    valid = (abs_pos <= posb) & (abs_pos >= 0)
    if window is not None:
        valid &= abs_pos > (posb - window)
    return valid


def paged_attention_decode(p, x, k_pages, v_pages, table, pos, active,
                           cfg: ArchConfig, *, window: Optional[int] = None,
                           attn_impl: str = "xla"):
    """One layer's decode over the paged pool.

    x: (B,1,D) hidden; k_pages/v_pages: (P, page, K, hd) this layer's pool;
    table: (B, max_pages) int32 page ids (0 = scratch); pos: (B,) int32
    absolute position per slot; active: (B,) bool live-request mask.
    Returns (out (B,1,D), (k_pages, v_pages)).
    """
    cd = cfg.dtype("compute")
    B = x.shape[0]
    _, page, K, hd = k_pages.shape
    W = table.shape[1] * page

    q, k, v = L._project_qkv(p, x, None, cfg)
    posb = pos[:, None].astype(jnp.int32)            # (B, 1)
    q = L.rope(q, posb, cfg.rope_theta)
    k = L.rope(k, posb, cfg.rope_theta)

    slot = pos % W if window is not None else pos
    page_idx = slot // page
    in_page = slot % page
    pid = jnp.take_along_axis(table, page_idx[:, None], axis=1)[:, 0]  # (B,)

    kn = k[:, 0].astype(k_pages.dtype)               # (B, K, hd)
    vn = v[:, 0].astype(v_pages.dtype)
    act = active[:, None, None]
    oldk = k_pages[pid, in_page]
    oldv = v_pages[pid, in_page]
    k_pages = k_pages.at[pid, in_page].set(jnp.where(act, kn, oldk))
    v_pages = v_pages.at[pid, in_page].set(jnp.where(act, vn, oldv))

    ck = k_pages[table].reshape(B, W, K, hd)         # the dense ring view
    cv = v_pages[table].reshape(B, W, K, hd)

    if attn_impl == "pallas" and window is None:
        from repro.kernels.flash_attention import ops as fa_ops
        out = fa_ops.flash_attention(q, ck.astype(cd), cv.astype(cd),
                                     causal=True, q_offsets=pos)
    else:
        valid = _valid_mask(pos, W, window)
        scores = L._grouped_scores(q, ck.astype(cd)).astype(jnp.float32)
        scores = scores + jnp.where(valid, 0.0, -1e30)[:, None, None, None, :]
        w = jax.nn.softmax(scores, axis=-1).astype(cd)
        out = L._apply_scores(w, cv.astype(cd))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return y, (k_pages, v_pages)


def paged_decode_step(params, pages, table, tokens, pos, active,
                      cfg: ArchConfig, *, window: Optional[int] = None,
                      attn_impl: str = "xla"):
    """One continuous-batching decode step for dense/moe stacks.

    pages: {"k","v"}: (L, P, page, K, hd); table: (B, max_pages) shared by
    all layers; tokens: (B,1) int32; pos: (B,) int32; active: (B,) bool.
    Returns (logits (B,1,V) fp32, new pages). Mirrors
    ``transformer.decode_step``'s layer scan so the math bit-matches.
    """
    if window is None:
        window = cfg.sliding_window
    t = cfg.arch_type
    if t not in ("dense", "moe"):
        raise ValueError(f"paged decode supports dense/moe, not {t!r}")
    x = L.embed(params["embed"], tokens, cfg)

    def body(h, xs):
        bp, kp, vp = xs
        a, (nkp, nvp) = paged_attention_decode(
            bp["attn"], L.rms_norm(h, bp["ln1"], cfg.norm_eps), kp, vp,
            table, pos, active, cfg, window=window, attn_impl=attn_impl)
        h = h + a
        h2 = L.rms_norm(h, bp["ln2"], cfg.norm_eps)
        if t == "dense":
            h = h + L.mlp_forward(bp["mlp"], h2, cfg)
        else:
            y, _ = M.moe_forward(bp["moe"], h2, cfg)
            h = h + y
        return h, (nkp, nvp)

    x, (nk, nv) = jax.lax.scan(body, x, (params["blocks"],
                                         pages["k"], pages["v"]))
    x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = L.unembed(params["embed"], x, cfg)
    return logits, {"k": nk, "v": nv}
