"""Pallas TPU kernel: convolution by fused lowering + GEMM (paper §III
adapted to TPU; design notes in docs/lowering_conv.md).

The paper materializes the lowered matrix for the whole batch in DRAM and
issues one big BLAS GEMM — trading memory footprint for GEMM efficiency,
bounded by off-chip memory. On TPU the analogous boundary is VMEM: this
kernel *never* materializes the lowered matrix in HBM. Each grid step loads
a (b_p, H, W, Cin) image block into VMEM, builds the lowered patch matrix
(b_p*rb*Wo, kh*kw*Cin) in registers/VMEM, and feeds a single MXU GEMM
against the (kh*kw*Cin, Cout) kernel matrix.

The paper's b_p knob (images lowered per GEMM) is the batch-block dimension
of the BlockSpec; the rows-block rb tiles output rows so the GEMM M dim
stays VMEM-resident. ``vmem_bytes`` exposes the footprint model
(paper Fig. 4c: memory grows linearly in b_p).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def largest_divisor(n: int, cap: int) -> int:
    """Largest divisor of n that is <= cap (>= 1). O(sqrt n) via divisor
    pairs instead of decrement-by-1 probing."""
    cap = max(1, min(cap, n))
    best = 1
    d = 1
    while d * d <= n:
        if n % d == 0:
            if d <= cap:
                best = max(best, d)
            if n // d <= cap:
                best = max(best, n // d)
        d += 1
    return best


def choose_tiles(b: int, ho: int, bp: int, rb: int) -> tuple:
    """Resolve requested (b_p, r_b) to the tile sizes the kernel will
    actually run: the largest divisors of the batch / output-rows not
    exceeding the request. Exposed so benchmarks can report the real
    tiling instead of the requested one."""
    return largest_divisor(b, bp), largest_divisor(ho, rb)


def _lower_block(d, *, kh, kw, stride, rb, wo, ir):
    """Lower one (bp, H, W, Cin) image block into the patch matrix for
    output-row tile ``ir``: (bp*rb*wo, kh*kw*Cin). Shared by the forward
    kernel and the wgrad kernel (docs/lowering_conv.md)."""
    bp, H, W, cin = d.shape
    rows_in = (rb - 1) * stride + kh
    d_rows = jax.lax.dynamic_slice(
        d, (0, ir * rb * stride, 0, 0), (bp, rows_in, W, cin))
    cols = []
    for i in range(kh):
        for j in range(kw):
            sl = jax.lax.slice(d_rows, (0, i, j, 0),
                               (bp, i + (rb - 1) * stride + 1,
                                j + (wo - 1) * stride + 1, cin),
                               (1, stride, stride, 1))
            cols.append(sl)                        # (bp, rb, wo, cin)
    low = jnp.stack(cols, axis=3)                  # (bp, rb, wo, kh*kw, cin)
    return low.reshape(bp * rb * wo, kh * kw * cin)


def _kernel(d_ref, k_ref, r_ref, *, kh, kw, stride, rb, wo):
    d_hat = _lower_block(d_ref[...], kh=kh, kw=kw, stride=stride, rb=rb,
                         wo=wo, ir=pl.program_id(1))
    r = jnp.dot(d_hat, k_ref[...],                 # MXU GEMM
                preferred_element_type=jnp.float32)
    bp = d_ref.shape[0]
    r_ref[...] = r.reshape(bp, rb, wo, -1).astype(r_ref.dtype)


def _kernel_with_lowered(d_ref, k_ref, r_ref, low_ref, *, kh, kw, stride, rb,
                         wo):
    d_hat = _lower_block(d_ref[...], kh=kh, kw=kw, stride=stride, rb=rb,
                         wo=wo, ir=pl.program_id(1))
    r = jnp.dot(d_hat, k_ref[...],
                preferred_element_type=jnp.float32)
    bp = d_ref.shape[0]
    r_ref[...] = r.reshape(bp, rb, wo, -1).astype(r_ref.dtype)
    low_ref[...] = d_hat.reshape(bp, rb, wo, kh * kw * d_ref.shape[3]) \
                        .astype(low_ref.dtype)


def lowering_conv_pallas(x: jax.Array, w: jax.Array, *, stride: int = 1,
                         bp: int = 8, rb: int = 8, interpret: bool = False,
                         return_lowered: bool = False):
    """x: (B,H,W,Cin); w: (kh,kw,Cin,Cout); VALID padding.

    bp: images lowered per GEMM (paper's b_p); rb: output-row tile.
    With ``return_lowered`` also emits the lowered patch matrix
    (B, Ho, Wo, kh*kw*Cin) — the residual the custom-VJP backward reuses
    (the paper's trade-memory-for-GEMM move applied to backprop: one extra
    HBM tensor instead of re-lowering in the backward pass).
    """
    b, h, wdim, cin = x.shape
    kh, kw, _, cout = w.shape
    ho = (h - kh) // stride + 1
    wo = (wdim - kw) // stride + 1
    bp, rb = choose_tiles(b, ho, bp, rb)
    k_hat = w.reshape(kh * kw * cin, cout)

    grid = (b // bp, ho // rb)
    kern = _kernel_with_lowered if return_lowered else _kernel
    out_specs = pl.BlockSpec((bp, rb, wo, cout), lambda ib, ir: (ib, ir, 0, 0))
    out_shape = jax.ShapeDtypeStruct((b, ho, wo, cout), x.dtype)
    if return_lowered:
        out_specs = [out_specs,
                     pl.BlockSpec((bp, rb, wo, kh * kw * cin),
                                  lambda ib, ir: (ib, ir, 0, 0))]
        out_shape = [out_shape,
                     jax.ShapeDtypeStruct((b, ho, wo, kh * kw * cin), x.dtype)]
    return pl.pallas_call(
        functools.partial(kern, kh=kh, kw=kw, stride=stride, rb=rb, wo=wo),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, h, wdim, cin), lambda ib, ir: (ib, 0, 0, 0)),
            pl.BlockSpec((kh * kw * cin, cout), lambda ib, ir: (0, 0)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(x, k_hat)


def vmem_bytes(*, bp: int, rb: int, h: int, w: int, cin: int, kh: int, kw: int,
               cout: int, stride: int = 1, itemsize: int = 4,
               pass_: str = "fwd") -> int:
    """VMEM working set of one grid step — the TPU version of the paper's
    Fig. 4(c) linear-in-b_p memory model, extended to the backward kernels.

    pass_:
      "fwd"    image block + lowered tile + kernel matrix + output tile
      "wgrad"  lowered-residual tile + dy tile + (K, Cout) accumulator
               (``bwd.wgrad_pallas``: consumes the forward's lowered
               residual, so no image block is resident)
      "dgrad"  dy block + kernel matrix + dcols tile + dx image block
               (``bwd.dgrad_pallas``: rb is ignored — the col2im scatter
               needs all output rows of a batch block at once)
    """
    ho = (h - kh) // stride + 1
    wo = (w - kw) // stride + 1
    K = kh * kw * cin
    if pass_ == "fwd":
        terms = (bp * h * w * cin,          # image block
                 bp * rb * wo * K,          # lowered tile (registers/VMEM)
                 K * cout,                  # kernel matrix
                 bp * rb * wo * cout)       # output tile
    elif pass_ == "wgrad":
        terms = (bp * rb * wo * K,          # lowered-residual tile
                 bp * rb * wo * cout,       # dy tile
                 K * cout)                  # wgrad accumulator
    elif pass_ == "dgrad":
        terms = (bp * ho * wo * cout,       # dy block (all rows)
                 K * cout,                  # kernel matrix
                 bp * ho * wo * K,          # dcols tile
                 bp * h * w * cin)          # dx image block
    else:
        raise ValueError(f"unknown pass_ {pass_!r} "
                         "(expected fwd | wgrad | dgrad)")
    return sum(terms) * itemsize
