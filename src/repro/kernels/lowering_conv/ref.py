"""Pure-jnp oracle for the lowering+GEMM convolution (paper §III, Fig. 2).

Two references: XLA's native conv, and an explicit lowering/GEMM/lifting
pipeline that mirrors the paper's three logical steps (used to check the
kernel implements the *same algorithm*, not just the same function).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def conv_ref(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """x: (B, H, W, Cin); w: (kh, kw, Cin, Cout); VALID padding."""
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def lower(x: jax.Array, kh: int, kw: int, stride: int = 1) -> jax.Array:
    """Lowering phase: (B,H,W,Cin) -> D_hat (B*Ho*Wo, kh*kw*Cin).
    Data replication factor = kh*kw/stride^2 (paper App C-A1)."""
    b, h, w, cin = x.shape
    ho = (h - kh) // stride + 1
    wo = (w - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            sl = jax.lax.slice(x, (0, i, j, 0),
                               (b, i + (ho - 1) * stride + 1,
                                j + (wo - 1) * stride + 1, cin),
                               (1, stride, stride, 1))
            cols.append(sl)                       # (B, Ho, Wo, Cin)
    low = jnp.stack(cols, axis=3)                 # (B, Ho, Wo, kh*kw, Cin)
    return low.reshape(b * ho * wo, kh * kw * cin)


def lowered_conv_ref(x: jax.Array, w: jax.Array, stride: int = 1) -> jax.Array:
    """Lowering -> one big GEMM -> lifting (the paper's CPU-optimal plan
    with b_p = b)."""
    b, h, _, cin = x.shape
    kh, kw, _, cout = w.shape
    ho = (h - kh) // stride + 1
    wo = (x.shape[2] - kw) // stride + 1
    d_hat = lower(x, kh, kw, stride)                    # (B*Ho*Wo, khkwCin)
    k_hat = w.reshape(kh * kw * cin, cout)              # no kernel replication
    r_hat = d_hat @ k_hat                               # GEMM
    return r_hat.reshape(b, ho, wo, cout)               # lifting
