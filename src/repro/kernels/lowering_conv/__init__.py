from repro.kernels.lowering_conv import ops, ref
from repro.kernels.lowering_conv.lowering_conv import (lowering_conv_pallas,
                                                       vmem_bytes)
