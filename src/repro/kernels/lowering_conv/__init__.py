from repro.kernels.lowering_conv import autotune, bwd, ops, ref
from repro.kernels.lowering_conv.lowering_conv import (choose_tiles,
                                                       largest_divisor,
                                                       lowering_conv_pallas,
                                                       vmem_bytes)
