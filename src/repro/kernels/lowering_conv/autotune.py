"""Per-layer tile autotuning for the lowering conv (paper Fig. 4: the b_p
sweep, automated).

``autotune_tiles`` probes the ``choose_tiles``-resolved (b_p, r_b)
candidates that fit under the ``vmem_bytes`` budget by timing the actual
op (forward + backward through the custom VJP) with ``engine.timing``,
and caches the winner per (input shape, kernel shape, stride, interpret).
Model code (``models.cnn._conv``) looks the cached choice up at trace
time via ``cached_tiles`` and falls back to the defaults when the layer
was never probed.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.engine import timing
from repro.kernels.lowering_conv.lowering_conv import choose_tiles, vmem_bytes

DEFAULT_TILES = (8, 8)
# generous CPU-probe default; on real TPU pass the core's VMEM (~16 MB)
# minus headroom for double buffering
DEFAULT_BUDGET_BYTES = 4 << 20

# geometry key -> ((b_p, r_b), budget_bytes the probe ran under)
_TILE_CACHE: Dict[tuple, Tuple[Tuple[int, int], int]] = {}


def _cache_key(x_shape, w_shape, stride: int, interpret: bool) -> tuple:
    """Keyed on the layer geometry WITHOUT the batch dimension: the engine
    traces the same conv at batch/g (group vmap) or batch/(g*k) (per-device
    shard), and a (b_p, r_b) probed at the global batch stays valid at any
    of them — ``choose_tiles`` re-clamps b_p to a divisor of whatever batch
    the kernel actually sees."""
    return (tuple(x_shape)[1:], tuple(w_shape), int(stride), bool(interpret))


def clear_tile_cache() -> None:
    _TILE_CACHE.clear()


def cached_tiles(x_shape, w_shape, stride: int,
                 interpret: bool = True) -> Tuple[int, int]:
    """The autotuned (b_p, r_b) for this layer geometry (batch-agnostic —
    see ``_cache_key``), or the defaults if it was never probed."""
    hit = _TILE_CACHE.get(_cache_key(x_shape, w_shape, stride, interpret))
    return hit[0] if hit is not None else DEFAULT_TILES


def _max_vmem(bp: int, rb: int, x_shape, w_shape, stride: int,
              itemsize: int = 4) -> int:
    """Worst-case working set of (b_p, r_b) across fwd/wgrad/dgrad."""
    _, h, w, cin = x_shape
    kh, kw, _, cout = w_shape
    geom = dict(h=h, w=w, cin=cin, kh=kh, kw=kw, cout=cout, stride=stride,
                itemsize=itemsize)
    return max(vmem_bytes(bp=bp, rb=rb, pass_=p, **geom)
               for p in ("fwd", "wgrad", "dgrad"))


def tile_candidates(x_shape, w_shape, stride: int, *,
                    budget_bytes: int = DEFAULT_BUDGET_BYTES,
                    itemsize: int = 4) -> List[Tuple[int, int]]:
    """Distinct (b_p, r_b) divisor pairs whose forward AND backward VMEM
    working sets (``vmem_bytes`` pass_ = fwd / wgrad / dgrad) fit the
    budget. Always contains at least (1, 1)."""
    b, h, w, cin = x_shape
    kh, kw, _, cout = w_shape
    ho = (h - kh) // stride + 1
    geom = dict(h=h, w=w, cin=cin, kh=kh, kw=kw, cout=cout, stride=stride,
                itemsize=itemsize)
    seen, out = set(), []
    for bp_req in sorted({1, 2, 4, 8, 16, 32, b}):
        for rb_req in sorted({1, 2, 4, 8, 16, ho}):
            bp, rb = choose_tiles(b, ho, bp_req, rb_req)
            if (bp, rb) in seen:
                continue
            seen.add((bp, rb))
            need = max(vmem_bytes(bp=bp, rb=rb, pass_=p, **geom)
                       for p in ("fwd", "wgrad", "dgrad"))
            if need <= budget_bytes:
                out.append((bp, rb))
    if not out:
        out = [(1, 1)]
    return sorted(out)


def autotune_tiles(x_shape, w_shape, stride: int = 1, *,
                   budget_bytes: int = DEFAULT_BUDGET_BYTES,
                   interpret: bool = True, warmup: int = 1, iters: int = 3,
                   key: Optional[jax.Array] = None) -> Tuple[int, int]:
    """Probe every in-budget tile candidate on the real op (forward +
    backward, jit-compiled) and cache the fastest. Idempotent per layer:
    a cache hit returns immediately without re-probing — unless the
    cached choice no longer fits a (smaller) ``budget_bytes``, which
    forces a re-probe under the new budget. (A larger budget keeps the
    cached choice: still valid, possibly conservative.)"""
    ck = _cache_key(x_shape, w_shape, stride, interpret)
    hit = _TILE_CACHE.get(ck)
    if hit is not None:
        tiles, probed_budget = hit
        if budget_bytes >= probed_budget or \
                _max_vmem(*tiles, x_shape, w_shape, stride) <= budget_bytes:
            return tiles
    from repro.kernels.lowering_conv import ops   # circular-at-import guard

    if key is None:
        key = jax.random.PRNGKey(0)
    kx, kw_ = jax.random.split(key)
    x = jax.random.normal(kx, x_shape, jnp.float32)
    w = jax.random.normal(kw_, w_shape, jnp.float32) * 0.1

    def step_for(bp, rb):
        def fwd_bwd(x, w):
            y, vjp = jax.vjp(
                lambda x, w: ops.lowering_conv(x, w, stride=stride, bp=bp,
                                               rb=rb, interpret=interpret),
                x, w)
            return jax.tree.map(jnp.sum, vjp(jnp.ones_like(y)))
        return jax.jit(fwd_bwd)

    from repro.obs import spans
    cands = tile_candidates(x_shape, w_shape, stride,
                            budget_bytes=budget_bytes)
    with spans.span("autotune.conv_tiles", candidates=len(cands),
                    x_shape=tuple(x_shape), w_shape=tuple(w_shape),
                    stride=stride) as outer:
        best, best_t = DEFAULT_TILES, float("inf")
        for bp, rb in cands:
            step = step_for(bp, rb)
            with spans.span("autotune.candidate", bp=bp, rb=rb) as sp:
                stats = timing.probe(lambda: step(x, w), warmup=warmup,
                                     iters=iters)
                sp.set(min_us=stats.min_s * 1e6)
            if stats.min_s < best_t:
                best, best_t = (bp, rb), stats.min_s
        outer.set(best_bp=best[0], best_rb=best[1],
                  best_min_us=best_t * 1e6)
    _TILE_CACHE[ck] = (best, budget_bytes)
    return best
