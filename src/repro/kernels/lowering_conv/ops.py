"""Jit'd public wrapper for the lowering-conv kernel."""
from __future__ import annotations

import functools

import jax

from repro.kernels.lowering_conv.lowering_conv import lowering_conv_pallas
from repro.kernels.lowering_conv.ref import lowered_conv_ref


@functools.partial(jax.jit, static_argnames=("stride", "bp", "rb", "interpret"))
def lowering_conv(x, w, *, stride: int = 1, bp: int = 8, rb: int = 8,
                  interpret: bool = True):
    """Convolution via fused lowering+GEMM. On CPU (this container) the
    Pallas kernel runs in interpret mode; pass interpret=False on real TPU.
    """
    return lowering_conv_pallas(x, w, stride=stride, bp=bp, rb=rb,
                                interpret=interpret)


@functools.partial(jax.jit, static_argnames=("stride",))
def lowering_conv_xla(x, w, *, stride: int = 1):
    """XLA fallback implementing the same lowering/GEMM algorithm (used by
    model code on non-TPU backends and by the dry-run)."""
    return lowered_conv_ref(x, w, stride=stride)
