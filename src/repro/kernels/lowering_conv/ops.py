"""Jit'd public wrappers for the lowering conv — now fully trainable.

``lowering_conv`` (Pallas) and ``lowering_conv_xla`` (same algorithm
through XLA) carry a ``custom_vjp`` whose backward expresses both
gradients as batched GEMMs over the *same* lowered patch matrix the
forward built (``bwd.py``; design in docs/lowering_conv.md):

  wgrad = lowered(x)^T @ dy        reusing the forward's lowered residual
  dgrad = dy @ K_hat^T, col2im     one GEMM + the lifting phase transposed

``needs_dgrad=False`` skips the input gradient entirely (Caffe's
``propagate_down=false`` for data-fed layers): a custom_vjp is opaque to
JAX's dead-code elimination, so the first conv layer of a network must
say so explicitly — generic autodiff gets the same effect from DCE.

``lowering_conv_autodiff`` is the pre-custom-VJP formulation (generic XLA
autodiff through the lowering), kept as the baseline the throughput bench
compares against.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.lowering_conv import bwd
from repro.kernels.lowering_conv.lowering_conv import lowering_conv_pallas
from repro.kernels.lowering_conv.ref import lower, lowered_conv_ref


# ---------------------------------------------------------------------------
# XLA path (the CPU training path)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _lc_xla(x, w, stride, needs_dgrad, x_shape):
    return lowered_conv_ref(x, w, stride=stride)


def _lc_xla_fwd(x, w, stride, needs_dgrad, x_shape):
    b, h, _, cin = x.shape
    kh, kw, _, cout = w.shape
    ho = (h - kh) // stride + 1
    wo = (x.shape[2] - kw) // stride + 1
    d_hat = lower(x, kh, kw, stride)                 # lowering phase
    r = (d_hat @ w.reshape(kh * kw * cin, cout))     # one big GEMM
    return r.reshape(b, ho, wo, cout), (d_hat, w)    # d_hat is the residual


def _lc_xla_bwd(stride, needs_dgrad, x_shape, res, dy):
    d_hat, w = res
    dw = bwd.wgrad_xla(d_hat, dy, w.shape)
    if needs_dgrad:
        dx = bwd.dgrad_xla(dy, w, x_shape, stride)
    else:
        dx = jnp.zeros(x_shape, dy.dtype)
    return dx, dw


_lc_xla.defvjp(_lc_xla_fwd, _lc_xla_bwd)


def lowering_conv_xla_traced(x, w, *, stride: int = 1,
                             needs_dgrad: bool = True):
    """Un-jitted form for call sites already inside a jitted (and possibly
    vmapped) training step — a nested jit under the engine's group-vmap
    costs ~2x on CPU. Model code (``models.cnn``) uses this."""
    return _lc_xla(x, w, stride, needs_dgrad, tuple(x.shape))


@functools.partial(jax.jit, static_argnames=("stride", "needs_dgrad"))
def lowering_conv_xla(x, w, *, stride: int = 1, needs_dgrad: bool = True):
    """Convolution via lowering + one big GEMM through XLA (the paper's
    CPU plan with b_p = b), with the custom batched-GEMM backward."""
    return lowering_conv_xla_traced(x, w, stride=stride,
                                    needs_dgrad=needs_dgrad)


# ---------------------------------------------------------------------------
# Pallas path
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4, 5, 6, 7))
def _lc_pallas(x, w, stride, bp, rb, interpret, needs_dgrad, x_shape):
    return lowering_conv_pallas(x, w, stride=stride, bp=bp, rb=rb,
                                interpret=interpret)


def _lc_pallas_fwd(x, w, stride, bp, rb, interpret, needs_dgrad, x_shape):
    r, lowered = lowering_conv_pallas(x, w, stride=stride, bp=bp, rb=rb,
                                      interpret=interpret,
                                      return_lowered=True)
    return r, (lowered, w)


def _lc_pallas_bwd(stride, bp, rb, interpret, needs_dgrad, x_shape, res, dy):
    lowered, w = res
    dw = bwd.wgrad_pallas(lowered, dy, w.shape, bp=bp, rb=rb,
                          interpret=interpret)
    if needs_dgrad:
        dx = bwd.dgrad_pallas(dy, w, x_shape, stride=stride, bp=bp,
                              interpret=interpret)
    else:
        dx = jnp.zeros(x_shape, dy.dtype)
    return dx, dw.astype(w.dtype)


_lc_pallas.defvjp(_lc_pallas_fwd, _lc_pallas_bwd)


def lowering_conv_traced(x, w, *, stride: int = 1, bp: int = 8, rb: int = 8,
                         interpret: bool = True, needs_dgrad: bool = True):
    """Un-jitted Pallas form (see ``lowering_conv_xla_traced``)."""
    return _lc_pallas(x, w, stride, bp, rb, interpret, needs_dgrad,
                      tuple(x.shape))


@functools.partial(jax.jit, static_argnames=("stride", "bp", "rb",
                                             "interpret", "needs_dgrad"))
def lowering_conv(x, w, *, stride: int = 1, bp: int = 8, rb: int = 8,
                  interpret: bool = True, needs_dgrad: bool = True):
    """Convolution via fused lowering+GEMM (Pallas), trainable through the
    batched-GEMM backward kernels. On CPU (this container) the kernels run
    in interpret mode; pass interpret=False on real TPU. Tile sizes come
    from ``autotune.cached_tiles`` when the caller has probed them.
    """
    return lowering_conv_traced(x, w, stride=stride, bp=bp, rb=rb,
                                interpret=interpret, needs_dgrad=needs_dgrad)


# ---------------------------------------------------------------------------
# Generic-autodiff baseline
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("stride",))
def lowering_conv_autodiff(x, w, *, stride: int = 1):
    """The same lowering/GEMM algorithm differentiated by generic XLA
    autodiff — what ``lowering_conv_xla`` was before the custom VJP. The
    throughput bench's baseline (bench_cnn_throughput)."""
    return lowered_conv_ref(x, w, stride=stride)
