"""Backward pass of the lowering conv as batched GEMMs (paper §III applied
to backprop; docs/lowering_conv.md).

Both gradients are GEMMs over the *same* lowered patch matrix the forward
already built:

  wgrad   dW_hat = lowered(x)^T @ dY_hat          one (K, M) x (M, Cout) GEMM
  dgrad   dCols  = dY_hat @ K_hat^T               one (M, Cout) x (Cout, K) GEMM
          dX     = col2im(dCols)                  scatter of the K = kh*kw*Cin
                                                  patch columns back to pixels

The wgrad consumes the forward's lowered residual instead of re-lowering —
the paper's trade-memory-for-GEMM move applied to the backward pass. Two
implementations of each: an XLA reference (``*_xla``, the CPU training
path) and a Pallas kernel (``*_pallas``, validated in interpret mode on
CPU, tiled for VMEM on real TPU via the same ``choose_tiles`` /
``vmem_bytes(pass_=...)`` model as the forward).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.lowering_conv.lowering_conv import choose_tiles


# ---------------------------------------------------------------------------
# XLA reference paths
# ---------------------------------------------------------------------------

def wgrad_xla(lowered: jax.Array, dy: jax.Array, kshape) -> jax.Array:
    """lowered: (M, kh*kw*Cin) forward residual; dy: (..., Cout) cotangent.
    Returns dW (kh, kw, Cin, Cout) via one GEMM — no re-lowering."""
    kh, kw, cin, cout = kshape
    dy_flat = dy.reshape(-1, cout)
    return (lowered.T @ dy_flat).reshape(kh, kw, cin, cout)


def _col2im_accumulate(g, h: int, w: int, kh: int, kw: int,
                       stride: int) -> jax.Array:
    """The col2im core, shared by the XLA form and the Pallas dgrad
    kernel body: accumulate patch-column gradients g (B, Ho, Wo, kh*kw,
    Cin) onto a (B, H, W, Cin) grid via kh*kw interior-padded adds —
    dense and vectorizable, no scatter op."""
    b, ho, wo, _, cin = g.shape
    dx = jnp.zeros((b, h, w, cin), g.dtype)
    zero = jnp.zeros((), g.dtype)
    idx = 0
    for i in range(kh):
        for j in range(kw):
            cfg = ((0, 0, 0),
                   (i, h - (i + (ho - 1) * stride + 1), stride - 1),
                   (j, w - (j + (wo - 1) * stride + 1), stride - 1),
                   (0, 0, 0))
            dx = dx + jax.lax.pad(g[:, :, :, idx, :], zero, cfg)
            idx += 1
    return dx


def col2im_xla(dcols: jax.Array, x_shape, kh: int, kw: int,
               stride: int) -> jax.Array:
    """Scatter patch-column gradients (B*Ho*Wo, kh*kw*Cin) back onto the
    image grid (the lifting phase transposed)."""
    b, h, w, cin = x_shape
    ho = (h - kh) // stride + 1
    wo = (w - kw) // stride + 1
    g = dcols.reshape(b, ho, wo, kh * kw, cin)
    return _col2im_accumulate(g, h, w, kh, kw, stride)


def dgrad_xla(dy: jax.Array, w: jax.Array, x_shape,
              stride: int) -> jax.Array:
    """dX via one GEMM against the kernel matrix, then col2im."""
    kh, kw, cin, cout = w.shape
    dy_flat = dy.reshape(-1, cout)
    dcols = dy_flat @ w.reshape(kh * kw * cin, cout).T
    return col2im_xla(dcols, x_shape, kh, kw, stride)


# ---------------------------------------------------------------------------
# Pallas kernels
# ---------------------------------------------------------------------------

def _wgrad_kernel(low_ref, dy_ref, out_ref):
    """Accumulate lowered-tile^T @ dy-tile into the (K, Cout) output. The
    output block is the same for every grid step, so it stays VMEM-resident
    and the grid reduces into it (sequential grid, standard Pallas reduce
    pattern; holds in interpret mode too)."""
    first = (pl.program_id(0) == 0) & (pl.program_id(1) == 0)

    @pl.when(first)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    bp, rb, wo, K = low_ref.shape
    low = low_ref[...].reshape(bp * rb * wo, K)
    dy = dy_ref[...].reshape(bp * rb * wo, -1)
    out_ref[...] += jax.lax.dot_general(
        low, dy, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(out_ref.dtype)


def wgrad_pallas(lowered: jax.Array, dy: jax.Array, kshape, *, bp: int = 8,
                 rb: int = 8, interpret: bool = False) -> jax.Array:
    """lowered: (B, Ho, Wo, kh*kw*Cin) forward residual (``return_lowered``
    layout); dy: (B, Ho, Wo, Cout). Returns dW (kh, kw, Cin, Cout)."""
    kh, kw, cin, cout = kshape
    b, ho, wo, K = lowered.shape
    bp, rb = choose_tiles(b, ho, bp, rb)
    grid = (b // bp, ho // rb)
    dw_flat = pl.pallas_call(
        _wgrad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bp, rb, wo, K), lambda ib, ir: (ib, ir, 0, 0)),
            pl.BlockSpec((bp, rb, wo, cout), lambda ib, ir: (ib, ir, 0, 0)),
        ],
        out_specs=pl.BlockSpec((K, cout), lambda ib, ir: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((K, cout), lowered.dtype),
        interpret=interpret,
    )(lowered, dy)
    return dw_flat.reshape(kh, kw, cin, cout)


def _dgrad_kernel(dy_ref, kt_ref, dx_ref, *, kh, kw, stride, h, w):
    """One batch block: dcols = dy @ K_hat^T (GEMM), then the fused col2im
    scatter onto the (bp, H, W, Cin) image block — all rows of the block at
    once, so adjacent output-row tiles never race on overlapping pixels."""
    bp, ho, wo, cout = dy_ref.shape
    K = kt_ref.shape[1]
    cin = K // (kh * kw)
    dy = dy_ref[...].reshape(bp * ho * wo, cout)
    dcols = jnp.dot(dy, kt_ref[...],
                    preferred_element_type=jnp.float32)   # (M, K) GEMM
    g = dcols.reshape(bp, ho, wo, kh * kw, cin)
    dx = _col2im_accumulate(g.astype(jnp.float32), h, w, kh, kw, stride)
    dx_ref[...] = dx.astype(dx_ref.dtype)


def dgrad_pallas(dy: jax.Array, w: jax.Array, x_shape, *, stride: int = 1,
                 bp: int = 8, interpret: bool = False) -> jax.Array:
    """dy: (B, Ho, Wo, Cout); w: (kh, kw, Cin, Cout). Returns dX
    ``x_shape``. Grid over batch blocks only (see ``_dgrad_kernel``)."""
    b, h, wdim, cin = x_shape
    kh, kw, _, cout = w.shape
    ho, wo = dy.shape[1], dy.shape[2]
    bp, _ = choose_tiles(b, ho, bp, 1)
    kt = w.reshape(kh * kw * cin, cout).T            # (Cout, K)
    return pl.pallas_call(
        functools.partial(_dgrad_kernel, kh=kh, kw=kw, stride=stride,
                          h=h, w=wdim),
        grid=(b // bp,),
        in_specs=[
            pl.BlockSpec((bp, ho, wo, cout), lambda ib: (ib, 0, 0, 0)),
            pl.BlockSpec((cout, kh * kw * cin), lambda ib: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bp, h, wdim, cin), lambda ib: (ib, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x_shape, dy.dtype),
        interpret=interpret,
    )(dy, kt)
