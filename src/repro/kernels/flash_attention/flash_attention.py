"""Pallas TPU flash attention (causal / sliding-window).

Grid: (batch*heads, q_blocks, kv_blocks) with the kv dimension innermost and
sequential — running max / denominator / accumulator live in VMEM scratch
across kv steps (the classic flash recurrence, TPU-style: blocks sized for
VMEM, dots shaped for the 128x128 MXU).

GQA runs without materializing repeated K/V: when ``kv_group > 1`` the
query rows are laid out head-major (``b*H + h`` with ``h = kv_head *
kv_group + g``) while K/V keep one row per kv head (``b*K + kv_head``),
and the K/V BlockSpec *index maps* compute the kv row from the grid's
batch*head index — the same block arithmetic, one ``kv_group``-th of the
KV bytes streamed from HBM.

``q_offsets`` gives every batch*head row its own absolute query position
(the row's query index 0 sits at absolute position ``q_offsets[row]``) —
the decode hot path's contract, where a continuously-batched row decodes
one token at its own ``pos`` against a shared-capacity paged cache. With
offsets of 0 the masks reduce to the train/prefill causal forms bit-for-
bit (the offset is an integer add into the same comparison).

Sliding-window support doubles as the sub-quadratic path for the long_500k
input shape on dense architectures (``configs.base.INPUT_SHAPES``; the
window policy lives in ``launch.steps.effective_window``).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(off_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq, bk, causal, window, scale, n_kv):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                    # (bq, hd)
    k = k_ref[0]                                    # (bk, hd)
    v = v_ref[0]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    off = off_ref[0, 0]                             # absolute pos of q row 0
    qpos = off + iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = jnp.ones((bq, bk), dtype=bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_prev * alpha + p.sum(axis=-1, keepdims=True)
    acc_scr[...] = (acc_scr[...] * alpha
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == n_kv - 1)
    def _out():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, causal: bool = True, window=None,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False, kv_group: int = 1,
                           q_offsets=None):
    """q: (BH, Sq, hd); k, v: (BH // kv_group, Sk, hd) — batch and heads
    pre-folded, q head-major so kv row = (bh // (K*G))*K + (bh % (K*G))//G.
    ``q_offsets``: optional (BH,) int32 absolute position of each row's
    first query (decode: the row's token position; default 0)."""
    bh, sq, hd = q.shape
    if kv_group < 1 or bh % kv_group:
        raise ValueError(f"kv_group={kv_group} must divide BH={bh}")
    if k.shape[0] != bh // kv_group or v.shape[0] != bh // kv_group:
        raise ValueError(f"k/v rows {k.shape[0]} != BH/kv_group "
                         f"{bh // kv_group}")
    sk = k.shape[1]
    bq = min(bq, sq)
    while sq % bq:
        bq -= 1
    bk = min(bk, sk)
    while sk % bk:
        bk -= 1
    n_kv = sk // bk
    grid = (bh, sq // bq, n_kv)
    scale = 1.0 / math.sqrt(hd)
    if q_offsets is None:
        q_offsets = jnp.zeros((bh,), jnp.int32)
    offs = q_offsets.astype(jnp.int32).reshape(bh, 1)

    # head-major q rows: bh = batch*H + head with H = K*G, so the kv row
    # batch*K + head//G equals bh // G exactly (head < K*G) — the whole
    # GQA group map is one floor-divide in the K/V index maps, and with
    # kv_group == 1 it is the identity the pre-GQA wrapper compiled.
    def kv_row(b, _g=kv_group):
        return b // _g

    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, causal=causal, window=window,
                          scale=scale, n_kv=n_kv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0)),
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (kv_row(b), j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (kv_row(b), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
        scratch_shapes=[
            # running max / denom / accumulator, fp32 in VMEM
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(offs, q, k, v)
