"""Jit'd public wrapper: GQA-aware flash attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    bq: int = 128, bk: int = 128, interpret: bool = True):
    """q: (B, Sq, H, hd); k,v: (B, Sk, K, hd) with H = K*G (GQA: kv heads
    repeated to H inside the wrapper). Returns (B, Sq, H, hd).

    interpret=True on CPU (this container); False on real TPU.
    """
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    if kh != h:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], hd)
    of = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                bq=bq, bk=bk, interpret=interpret)
    return of.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
