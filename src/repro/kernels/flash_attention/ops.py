"""Jit'd public wrapper: GQA-aware flash attention.

GQA no longer materializes repeated K/V before the kernel (the old
``jnp.repeat`` doubled/quadrupled the KV bytes for every GQA config):
K/V are flattened to one row per *kv* head and the kernel's BlockSpec
index maps stream each kv row to its ``H/K`` query-head rows
(``flash_attention_pallas(kv_group=...)``). Bitwise-identical to the
repeat formulation — same blocks, same dot order — pinned by
``tests/test_kernels.py::test_flash_attention_gqa_no_repeat_bitwise``.

``q_offsets`` (per-batch absolute query positions) is the decode hot
path's handle: a continuously-batched decode step has one query per
request at that request's own position, scored against the request's
gathered cache rows (``repro.serving.decode``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas


@functools.partial(jax.jit,
                   static_argnames=("causal", "window", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    bq: int = 128, bk: int = 128, interpret: bool = True,
                    q_offsets=None):
    """q: (B, Sq, H, hd); k,v: (B, Sk, K, hd) with H = K*G (GQA: the
    query-head -> kv-head group map runs inside the kernel's flattened
    batch dimension; K/V are never repeated). ``q_offsets``: optional
    (B,) int32 absolute position of each batch row's first query (decode
    rows at per-request positions). Returns (B, Sq, H, hd).

    interpret=True on CPU (this container); False on real TPU.
    """
    b, sq, h, hd = q.shape
    kh = k.shape[2]
    if h % kh:
        raise ValueError(f"H={h} must be a multiple of K={kh}")
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kh, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kh, v.shape[1], hd)
    offs = None
    if q_offsets is not None:
        offs = jnp.broadcast_to(
            q_offsets.astype(jnp.int32)[:, None], (b, h)).reshape(b * h)
    of = flash_attention_pallas(qf, kf, vf, causal=causal, window=window,
                                bq=bq, bk=bk, interpret=interpret,
                                kv_group=h // kh, q_offsets=offs)
    return of.reshape(b, h, sq, hd).transpose(0, 2, 1, 3)
