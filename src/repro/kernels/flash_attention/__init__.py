from repro.kernels.flash_attention import ops, ref
from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
