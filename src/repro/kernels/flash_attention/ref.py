"""Pure-jnp oracle for flash attention (causal / sliding-window, MHA)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window=None):
    """q,k,v: (B, S, H, hd) (same head count — GQA handled by the wrapper).
    Returns (B, S, H, hd)."""
    hd = q.shape[-1]
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), dtype=bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    scores = jnp.where(ok, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v)
