from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.paged_attention import paged_attention_pallas
from repro.kernels.paged_attention.ref import paged_attention_ref, valid_mask

__all__ = ["paged_attention", "paged_attention_pallas",
           "paged_attention_ref", "valid_mask"]
