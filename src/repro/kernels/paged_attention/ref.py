"""Dense-gather oracle for paged decode attention.

Materializes exactly the ``(B, W, K, hd)`` ring view the pre-kernel
serving hot path gathered (``pool[table].reshape``), applies the
reference per-row validity mask, and runs the same grouped einsum /
softmax as ``serving.decode``'s XLA arm — the equality target the
in-kernel page walk is pinned against (full + sliding windows, ring
wrap, recycled slots, scratch-backed rows).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


def valid_mask(pos: jax.Array, W: int, window: Optional[int]) -> jax.Array:
    """Per-row ring validity, (B, W) bool: which of the W gathered slots
    hold positions row b may attend to at ``pos[b]``."""
    slots = jnp.arange(W)[None, :]
    posb = pos[:, None]
    if window is not None:
        base = posb - (posb % W)
        abs_pos = jnp.where(slots <= (posb % W), base + slots,
                            base - W + slots)
    else:
        abs_pos = jnp.broadcast_to(slots, (pos.shape[0], W))
    valid = (abs_pos <= posb) & (abs_pos >= 0)
    if window is not None:
        valid &= abs_pos > (posb - window)
    return valid


def paged_attention_ref(q, k_pages, v_pages, table, pos, *, window=None):
    """Same signature/layout as ``ops.paged_attention`` (q: (B,1,H,hd)),
    computed via the dense gathered copy."""
    b, sq, h, hd = q.shape
    _, page, kh, _ = k_pages.shape
    W = table.shape[1] * page
    g = h // kh
    ck = k_pages[table].reshape(b, W, kh, hd)
    cv = v_pages[table].reshape(b, W, kh, hd)
    qg = q.reshape(b, sq, kh, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, ck) / math.sqrt(hd)
    s = s.astype(jnp.float32)
    ok = valid_mask(pos, W, window)
    s = s + jnp.where(ok, 0.0, -1e30)[:, None, None, None, :]
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", w, cv)
    return out.reshape(b, sq, h, hd)
