"""Jit'd public wrapper: in-kernel paged flash-decode.

Takes the serving decode shapes as they are — q ``(B, 1, H, hd)`` (one
rotated query token per slot), the per-layer page pools, the slot page
tables and the per-row positions — and returns ``(B, 1, H, hd)``, the
layout ``serving.decode`` feeds the output projection. The GQA grouping
(H = K * G, head index ``k * G + g``) matches ``layers._grouped_scores``
so the paged kernel is a drop-in for the gathered dense path.

``interpret=True`` on CPU (this container); False on real TPU.
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.paged_attention.paged_attention import paged_attention_pallas


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_attention(q, k_pages, v_pages, table, pos, *, window=None,
                    interpret: bool = True):
    """q: (B, 1, H, hd); k_pages/v_pages: (P, page, K, hd) with H = K*G;
    table: (B, n_pages) int32 (page 0 = scratch); pos: (B,) int32 current
    absolute position per row (its K/V already written). ``window``
    enables ring semantics over the table's W = n_pages*page slots.
    Returns (B, 1, H, hd)."""
    b, sq, h, hd = q.shape
    if sq != 1:
        raise ValueError(f"paged decode takes one query token, got Sq={sq}")
    kh = k_pages.shape[2]
    if h % kh:
        raise ValueError(f"H={h} must be a multiple of K={kh}")
    qg = q.reshape(b, kh, h // kh, hd)           # head h = k*G + g, grouped
    out = paged_attention_pallas(qg, k_pages, v_pages, table, pos,
                                 window=window, interpret=interpret)
    return out.reshape(b, 1, h, hd)
