"""Pallas TPU paged flash-decode: the page table is walked *inside* the
kernel, so decode bandwidth scales with each request's live context, not
the pool's worst-case capacity.

The serving decode hot path used to gather every slot's pages into a
dense ``(B, W, K, hd)`` ring copy per layer per step — ``W`` bytes moved
whether the request had 9 live tokens or 900. Here the K/V BlockSpec
*index maps* read the page table (a scalar-prefetch operand, resident in
SMEM before the grid starts) to pick the physical page block for each
grid step: pages are consumed in place, zero dense materialization.

Grid: ``(B, K, n_pages)`` — batch rows, kv heads, then the slot's page
list innermost and sequential (the flash running max / denominator /
accumulator live in VMEM scratch across page steps). Three properties do
the roofline work:

- **Length-bounded walk.** Per-row ``pos`` (also scalar-prefetched)
  bounds the live page count ``jmax``; tail steps clamp their index map
  to the last live page — an unchanged block index means the pipeline
  skips the HBM fetch — and ``pl.when`` skips their compute entirely.
  Work scales with ``pos[b]``, not ``W = n_pages * page``.
- **Repeat-free GQA.** The kv-head grid dimension feeds the K/V index
  maps directly while the query block carries that head's ``G = H/K``
  query rows, so K/V bytes stream once per kv head — the same
  no-``jnp.repeat`` contract as ``flash_attention``'s ``kv_row`` trick,
  expressed as a grid axis instead of a row divide.
- **Ring-aware masking.** With ``window`` set the cache is a ring:
  slot ``s`` holds absolute position ``base + s`` or ``base - W + s``
  depending on which side of the write head it sits (the reference
  ``serving.decode._valid_mask`` per block). Once a row wraps
  (``pos >= W``) every page is live and the walk covers the table; the
  mask, not slot order, carries position — which is what let the old
  gathered-copy flash path reject sliding windows.

Masked/scratch-backed entries cannot leak into the value reduction: a
fully-masked page contributes ``p = exp(-inf - m) = 0`` rows once any
valid page has raised the running max, and every decode row has at least
its own just-written token valid (slot ``pos``), which the page walk
always visits.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _live_jmax(pos, *, page: int, n_pages: int, ring: bool):
    """Index of the last live page for a row at position ``pos`` (the
    current token's page for linear caches; the whole table once a ring
    row wraps). Clamped so stale positions of retired slots can never
    index past the table row."""
    jmax = pos // page
    if ring:
        jmax = jnp.where(pos >= n_pages * page, n_pages - 1, jmax)
    return jnp.minimum(jmax, n_pages - 1)


def _kernel(tbl_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
            m_scr, l_scr, acc_scr, *, page, n_pages, scale, window, W):
    b = pl.program_id(0)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[b]
    jmax = _live_jmax(pos, page=page, n_pages=n_pages,
                      ring=window is not None)

    @pl.when(j <= jmax)
    def _flash_step():
        q = q_ref[0, 0]                              # (G, hd)
        k = k_ref[0, :, 0]                           # (page, hd)
        v = v_ref[0, :, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        slot = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        if window is None:
            ok = slot <= pos
        else:
            # ring validity: the reference _valid_mask, one page at a time
            base = pos - pos % W
            absp = jnp.where(slot <= pos % W, base + slot, base - W + slot)
            ok = (absp <= pos) & (absp >= 0) & (absp > pos - window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[...]
        l_prev = l_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_scr[...] = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = (acc_scr[...] * alpha
                        + jnp.dot(p.astype(v.dtype), v,
                                  preferred_element_type=jnp.float32))
        m_scr[...] = m_new

    @pl.when(j == n_pages - 1)
    def _out():
        o_ref[0, 0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                       ).astype(o_ref.dtype)


def paged_attention_pallas(q, k_pages, v_pages, table, pos, *,
                           window=None, interpret: bool = False):
    """q: (B, K, G, hd) one query token per row, grouped by kv head;
    k_pages/v_pages: (P, page, K, hd) physical pools; table: (B, n_pages)
    int32 page ids; pos: (B,) int32 absolute position of each row's
    current token (already written into its page). Returns (B, K, G, hd).
    """
    B, K, G, hd = q.shape
    _, page, Kp, hdp = k_pages.shape
    if (Kp, hdp) != (K, hd):
        raise ValueError(f"pool heads/dims {(Kp, hdp)} != query {(K, hd)}")
    n_pages = table.shape[1]
    W = n_pages * page
    if window is not None and W > window:
        raise ValueError(f"ring of {n_pages}x{page} slots exceeds "
                         f"window={window}")
    scale = 1.0 / math.sqrt(hd)
    ring = window is not None

    def kv_map(b, h, j, tbl, pos_s):
        # THE table walk: clamp dead-tail steps to the last live page so
        # their block index repeats (no new fetch), then map the logical
        # page j to its physical page id.
        jj = jnp.minimum(j, _live_jmax(pos_s[b], page=page,
                                       n_pages=n_pages, ring=ring))
        return (tbl[b, jj], 0, h, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, K, n_pages),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, j, tbl, pos_s: (b, h, 0, 0)),
            pl.BlockSpec((1, page, 1, hd), kv_map),
            pl.BlockSpec((1, page, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, tbl, pos_s: (b, h, 0, 0)),
        scratch_shapes=[
            # running max / denom / accumulator, fp32 in VMEM
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_kernel, page=page, n_pages=n_pages, scale=scale,
                          window=window, W=W),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, K, G, hd), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), pos.astype(jnp.int32), q, k_pages, v_pages)
