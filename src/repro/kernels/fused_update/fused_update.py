"""Pallas TPU kernel: fused grouped momentum-SGD parameter update.

Applies the closed form of g sequential sub-steps (optim/closed_form.py)
to one parameter leaf in a single pass:

    W_new = cww*W + cwv*V + sum_i a_i * G[i]
    V_new = cvw*W + cvv*V + sum_i b_i * G[i]

The scan-based reference reads and writes every (W, V) leaf g times and
round-trips each leaf through an fp32 copy per sub-step. Here each grid
step loads one (block_rows, 128) tile of W/V plus the matching (g, ...)
gradient tile into VMEM, accumulates the weighted combination in fp32
*in registers/VMEM*, and writes the tile back once — HBM traffic drops
from O(g*(|W|+|V|)) to O(|W|+|V|) + the unavoidable g*|G| gradient reads.

Leaves of arbitrary shape are flattened and zero-padded to (rows, 128)
lane tiles; coefficients are compile-time Python floats (closed over the
static hyperparameters), so no scalar prefetch is needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.optim.closed_form import GroupedCoeffs

LANE = 128       # TPU lane width (last dim of every tile)


def _sublane(*dtypes) -> int:
    """Native TPU sublane multiple: 8 rows for 4-byte, 16 for 2-byte,
    32 for 1-byte dtypes. Blocks are shared across W/V/G, so take the
    strictest requirement."""
    return max(max(8, 32 // jnp.dtype(d).itemsize) for d in dtypes)


def _kernel(w_ref, v_ref, g_ref, wo_ref, vo_ref, *, coeffs: GroupedCoeffs):
    w = w_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    gs = g_ref[...].astype(jnp.float32)        # (g, block_rows, LANE)
    acc_w = coeffs.cww * w + coeffs.cwv * v
    acc_v = coeffs.cvw * w + coeffs.cvv * v
    for i in range(coeffs.num_groups):         # static unroll, g is small
        acc_w = acc_w + coeffs.a[i] * gs[i]
        acc_v = acc_v + coeffs.b[i] * gs[i]
    wo_ref[...] = acc_w.astype(wo_ref.dtype)
    vo_ref[...] = acc_v.astype(vo_ref.dtype)


def fused_update_pallas(w: jax.Array, v: jax.Array, gstack: jax.Array,
                        coeffs: GroupedCoeffs, *, block_rows: int = 256,
                        interpret: bool = False):
    """One leaf or one bucket slab: w/v any shape (a flat (n,) packing of
    several leaves works — everything is flattened to lane tiles anyway),
    gstack (g, *w.shape). Returns (w_new, v_new).

    On CPU (this container) run with interpret=True; the XLA reference in
    ref.py is the production non-TPU path.
    """
    g = gstack.shape[0]
    if g != coeffs.num_groups:
        raise ValueError(f"gstack has {g} groups, coeffs {coeffs.num_groups}")
    n = w.size
    sub = _sublane(w.dtype, v.dtype, gstack.dtype)
    rows = max(1, -(-n // LANE))
    br = max(sub, min(block_rows, -(-rows // sub) * sub))
    br = (br // sub) * sub
    rows_p = -(-rows // br) * br
    pad = rows_p * LANE - n
    w2 = jnp.pad(w.reshape(-1), (0, pad)).reshape(rows_p, LANE)
    v2 = jnp.pad(v.reshape(-1), (0, pad)).reshape(rows_p, LANE)
    g2 = jnp.pad(gstack.reshape(g, -1),
                 ((0, 0), (0, pad))).reshape(g, rows_p, LANE)

    wn, vn = pl.pallas_call(
        functools.partial(_kernel, coeffs=coeffs),
        grid=(rows_p // br,),
        in_specs=[
            pl.BlockSpec((br, LANE), lambda r: (r, 0)),
            pl.BlockSpec((br, LANE), lambda r: (r, 0)),
            pl.BlockSpec((g, br, LANE), lambda r: (0, r, 0)),
        ],
        out_specs=[pl.BlockSpec((br, LANE), lambda r: (r, 0)),
                   pl.BlockSpec((br, LANE), lambda r: (r, 0))],
        out_shape=[jax.ShapeDtypeStruct((rows_p, LANE), w.dtype),
                   jax.ShapeDtypeStruct((rows_p, LANE), v.dtype)],
        interpret=interpret,
    )(w2, v2, g2)
    return (wn.reshape(-1)[:n].reshape(w.shape),
            vn.reshape(-1)[:n].reshape(v.shape))
