"""Public wrappers for the fused grouped update: a jit'd per-leaf entry
point, the single-traversal tree-level update, and the per-bucket slab
entry used by the overlapped SPMD exchange (``engine.spmd``)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.fused_update.fused_update import fused_update_pallas
from repro.kernels.fused_update.ref import fused_update_ref
from repro.optim.closed_form import GroupedCoeffs


def _leaf_update(w, v, gstack, coeffs: GroupedCoeffs, *, impl: str,
                 block_rows: int, interpret):
    if interpret is None:    # compile natively on TPU, interpret elsewhere
        interpret = jax.default_backend() != "tpu"
    if impl == "pallas":
        return fused_update_pallas(w, v, gstack, coeffs,
                                   block_rows=block_rows, interpret=interpret)
    if impl != "xla":
        raise ValueError(f"unknown impl {impl!r}")
    return fused_update_ref(w, v, gstack, coeffs)


@functools.partial(jax.jit,
                   static_argnames=("coeffs", "impl", "block_rows",
                                    "interpret"))
def fused_update(w, v, gstack, *, coeffs: GroupedCoeffs, impl: str = "xla",
                 block_rows: int = 256, interpret=None):
    """One leaf. impl='pallas' runs the TPU kernel (compiled on TPU,
    interpret mode elsewhere when interpret is None); impl='xla' the
    reference combination (production path off-TPU)."""
    return _leaf_update(w, v, gstack, coeffs, impl=impl,
                        block_rows=block_rows, interpret=interpret)


def fused_bucket_update(w_slab, v_slab, gstack, *, coeffs: GroupedCoeffs,
                        impl: str = "xla", block_rows: int = 256,
                        interpret=None):
    """Per-bucket slab update for the overlapped SPMD exchange: ``w_slab``
    / ``v_slab`` are (n,) flat packings of a bucket's leaves
    (``engine.buckets.pack_bucket``), ``gstack`` the gathered (g, n)
    gradient slab. Both the Pallas kernel and the XLA reference are
    shape-agnostic elementwise combinations, so the slab result is
    bit-identical to the per-leaf updates it replaces. Not jitted: the
    caller traces inside ``shard_map``."""
    return _leaf_update(w_slab, v_slab, gstack, coeffs, impl=impl,
                        block_rows=block_rows, interpret=interpret)


def fused_group_update(params, grads, mom_buf, *, coeffs: GroupedCoeffs,
                       head_coeffs: GroupedCoeffs = None, head_mask=None,
                       impl: str = "xla", block_rows: int = 256,
                       interpret=None):
    """Whole-tree fused update in ONE traversal.

    grads: same tree as params with a leading (g, ...) group axis per leaf.
    head_mask: optional tree of Python bools — True leaves (merged-FC head)
    use ``head_coeffs`` (single averaged zero-staleness update), the rest
    ``coeffs`` (g sequential sub-steps, collapsed). Returns
    (new_params, new_mom).
    """
    flat_w, tree = jax.tree.flatten(params)
    # flatten_up_to validates grads/mom/mask against the params structure
    # (a bare zip would silently mis-pair leaves on tree mismatch)
    flat_g = tree.flatten_up_to(grads)
    flat_v = tree.flatten_up_to(mom_buf)
    flat_m = (tree.flatten_up_to(head_mask) if head_mask is not None
              else [False] * len(flat_w))
    new_w, new_v = [], []
    for w, g, v, is_head in zip(flat_w, flat_g, flat_v, flat_m):
        if is_head and head_coeffs is None:
            raise ValueError("head_mask marks head leaves but head_coeffs "
                             "was not provided")
        c = head_coeffs if is_head else coeffs
        wn, vn = _leaf_update(w, v, g, c, impl=impl, block_rows=block_rows,
                              interpret=interpret)
        new_w.append(wn)
        new_v.append(vn)
    return tree.unflatten(new_w), tree.unflatten(new_v)
