from repro.kernels.fused_update import ops, ref
from repro.kernels.fused_update.fused_update import fused_update_pallas
from repro.kernels.fused_update.ops import fused_group_update, fused_update
from repro.kernels.fused_update.ref import fused_update_ref
