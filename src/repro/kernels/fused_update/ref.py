"""Pure-jnp oracle for the fused grouped update (and the production path on
non-TPU backends): the same closed-form weighted combination, with the fp32
accumulation left to XLA fusion."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.closed_form import GroupedCoeffs


def fused_update_ref(w: jax.Array, v: jax.Array, gstack: jax.Array,
                     coeffs: GroupedCoeffs):
    """One leaf OR one bucket slab: w/v any shape (including a flat (n,)
    packing of several leaves), gstack (g, *w.shape). The combination is
    purely elementwise, so slab and per-leaf results are bit-identical.
    Returns (w_new, v_new)."""
    if gstack.shape[0] != coeffs.num_groups:
        raise ValueError(f"gstack has {gstack.shape[0]} groups, "
                         f"coeffs {coeffs.num_groups}")
    w32 = w.astype(jnp.float32)
    v32 = v.astype(jnp.float32)
    w_new = coeffs.cww * w32 + coeffs.cwv * v32
    v_new = coeffs.cvw * w32 + coeffs.cvv * v32
    # static unroll with Python-float coefficients: XLA fuses the whole
    # combination into ONE streaming pass over the stacked gradients
    # (a tensordot here lowers to a packed GEMM on CPU — far slower)
    for i in range(coeffs.num_groups):
        g32 = gstack[i].astype(jnp.float32)
        w_new = w_new + coeffs.a[i] * g32
        v_new = v_new + coeffs.b[i] * g32
    return w_new.astype(w.dtype), v_new.astype(v.dtype)
