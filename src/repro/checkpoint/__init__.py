from repro.checkpoint import checkpointing

__all__ = ["checkpointing"]
