"""Checkpointing: pytree save/restore (npz) + step metadata. The epoch-wise
optimizer (Algorithm 1) checkpoints at every epoch boundary (paper §V-B).

Naming contract: each leaf's npz key is its tree path, one escaped
segment per path entry joined with "/". Segments escape "\\" and "/"
(``_escape``), so a dict key containing "/" (or a str key that renders
like a list index) can never alias another leaf's name — ``save``
additionally asserts global uniqueness and fails loudly instead of
letting np.savez keep the last write.

Restore contract (mesh-sharded engines): each loaded array is
materialized through the *target* leaf's sharding when it has one
(``jax.device_put(arr, leaf.sharding)``), so resuming an mp-sharded
Engine run places every shard back on its device instead of silently
replicating on the default device (which would break donation and blow
up memory at scale). Dtypes must match exactly unless
``allow_cast=True`` — a silent cast can mask fp64-coefficient or
bf16-master drift between the saved and the resuming run.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Optional, Tuple

import jax
import numpy as np


def _escape(segment: str) -> str:
    """Escape a path segment so "/" joins cannot alias across segment
    boundaries: backslash first, then the separator itself."""
    return segment.replace("\\", "\\\\").replace("/", "\\/")


def _leaf_names(flat):
    """Escaped path-joined names for ``tree_flatten_with_path`` output
    (one name per (path, leaf) pair, order preserved)."""
    return ["/".join(_escape(str(getattr(p, "key", getattr(p, "idx", p))))
                     for p in path)
            for path, _ in flat]


def _flatten_with_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    names = _leaf_names(flat)
    out = {}
    for name, (path, leaf) in zip(names, flat):
        if name in out:
            raise ValueError(
                f"checkpoint name collision: two leaves flatten to "
                f"{name!r} — distinct tree paths must produce distinct "
                "names (escaped-path contract, module doc)")
        out[name] = np.asarray(leaf)
    return out


def save(path, tree, *, step: int = 0, extra: Optional[dict] = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays = _flatten_with_names(tree)
    np.savez(path.with_suffix(".npz"), **arrays)
    meta = {"step": step, "leaves": sorted(arrays), **(extra or {})}
    path.with_suffix(".json").write_text(json.dumps(meta, indent=2))


def restore(path, tree_like, *, allow_cast: bool = False) -> Tuple[object, int]:
    """Restore into the structure of ``tree_like``. Returns (tree, step).

    Each leaf keeps the target's placement: when the ``tree_like`` leaf
    carries a ``.sharding`` (a live mesh-sharded array), the loaded value
    is ``jax.device_put`` through it — shards land on their devices, not
    replicated on the default device. Dtype mismatches raise unless
    ``allow_cast=True`` (module doc).
    """
    path = Path(path)
    data = np.load(path.with_suffix(".npz"))
    meta = json.loads(path.with_suffix(".json").read_text())
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    names = _leaf_names(flat)
    leaves = []
    for name, (p, leaf) in zip(names, flat):
        arr = data[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {leaf.shape}")
        if arr.dtype != leaf.dtype:
            if not allow_cast:
                raise ValueError(
                    f"dtype mismatch for {name}: checkpoint has "
                    f"{arr.dtype}, target expects {leaf.dtype} "
                    "(pass allow_cast=True to cast explicitly)")
            arr = arr.astype(leaf.dtype)
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None:
            leaves.append(jax.device_put(arr, sharding))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree.structure(tree_like), leaves), int(meta["step"])


def latest(dirpath) -> Optional[Path]:
    d = Path(dirpath)
    if not d.exists():
        return None
    cands = sorted(d.glob("ckpt_*.json"))
    return cands[-1].with_suffix("") if cands else None
