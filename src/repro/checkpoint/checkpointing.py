"""Checkpointing: pytree save/restore (npz) + step metadata. The epoch-wise
optimizer (Algorithm 1) checkpoints at every epoch boundary (paper §V-B)."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Optional, Tuple

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[name] = np.asarray(leaf)
    return out


def save(path, tree, *, step: int = 0, extra: Optional[dict] = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays = _flatten_with_names(tree)
    np.savez(path.with_suffix(".npz"), **arrays)
    meta = {"step": step, "leaves": sorted(arrays), **(extra or {})}
    path.with_suffix(".json").write_text(json.dumps(meta, indent=2))


def restore(path, tree_like) -> Tuple[object, int]:
    """Restore into the structure of ``tree_like``. Returns (tree, step)."""
    path = Path(path)
    data = np.load(path.with_suffix(".npz"))
    meta = json.loads(path.with_suffix(".json").read_text())
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for p, leaf in flat:
        name = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in p)
        arr = data[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"shape mismatch for {name}: "
                             f"{arr.shape} vs {leaf.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return jax.tree_util.tree_unflatten(
        jax.tree.structure(tree_like), leaves), int(meta["step"])


def latest(dirpath) -> Optional[Path]:
    d = Path(dirpath)
    if not d.exists():
        return None
    cands = sorted(d.glob("ckpt_*.json"))
    return cands[-1].with_suffix("") if cands else None
