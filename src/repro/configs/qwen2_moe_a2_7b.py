"""qwen2-moe-a2.7b [moe] — 60 routed experts top-4 + 4 shared. [hf:Qwen/Qwen1.5-MoE-A2.7B]"""
import dataclasses

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                    # per-expert FFN width
    vocab_size=151_936,
    qkv_bias=True,
    moe=MoEConfig(num_experts=60, top_k=4, d_ff_expert=1408,
                  num_shared_experts=4),
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        head_dim=32, d_ff=64, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                      num_shared_experts=1))
