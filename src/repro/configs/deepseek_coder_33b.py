"""deepseek-coder-33b [dense] — llama-arch. [arXiv:2401.14196]"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    arch_type="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19_200,
    vocab_size=32_256,
    rope_theta=100_000.0,
    source="arXiv:2401.14196 (DeepSeek-Coder 33B)",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512)
