"""llama3-405b [dense] — 126L GQA, 128k vocab. [arXiv:2407.21783]"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    arch_type="dense",
    num_layers=126,
    d_model=16_384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53_248,
    vocab_size=128_256,
    rope_theta=500_000.0,
    # 405B params: fp32 params+momentum = 3.2 TB > 256x16GB. bf16 keeps the
    # single-pod dry-run within HBM; the multi-pod mesh is the realistic home.
    param_dtype="bfloat16",
    mom_dtype="bfloat16",
    source="arXiv:2407.21783 (Llama 3.1 405B)",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512,
        param_dtype="float32", mom_dtype="float32")
