"""whisper-base [audio] — enc-dec transformer backbone; conv frontend is a STUB
(input_specs supplies precomputed mel-frame embeddings). [arXiv:2212.04356]"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    arch_type="encdec",
    num_layers=6,                 # decoder layers
    encoder_layers=6,
    encoder_seq=1500,             # 30s audio -> 1500 frames after conv frontend (stubbed)
    d_model=512,
    num_heads=8,
    num_kv_heads=8,
    d_ff=2048,
    vocab_size=51_865,
    act="gelu",
    rope_theta=0.0,               # whisper uses learned/sinusoidal positions, not RoPE
    source="arXiv:2212.04356 (Whisper base)",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, encoder_layers=2, encoder_seq=64,
        d_model=128, num_heads=4, num_kv_heads=4, d_ff=256, vocab_size=512)
