"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, (rec,rec,attn) 1:2.
[arXiv:2402.19427 (Griffin)]"""
import dataclasses

from repro.configs.base import ArchConfig, HybridConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    num_layers=26,                # Griffin-2B depth; pattern (rec,rec,attn) cyclic
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,               # MQA
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    hybrid=HybridConfig(pattern=("rec", "rec", "attn"),
                        d_rnn=2560, local_window=2048, conv_width=4),
    act="gelu",
    source="arXiv:2402.19427 (RecurrentGemma/Griffin 2B)",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=3, d_model=128, num_heads=4, num_kv_heads=1,
        head_dim=32, d_ff=256, vocab_size=512,
        hybrid=HybridConfig(pattern=("rec", "rec", "attn"),
                            d_rnn=128, local_window=32, conv_width=4))
