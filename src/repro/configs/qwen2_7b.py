"""qwen2-7b [dense] — GQA kv=4, QKV bias. [arXiv:2407.10671]"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    head_dim=128,
    d_ff=18_944,
    vocab_size=152_064,
    qkv_bias=True,
    source="arXiv:2407.10671 (Qwen2-7B)",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512)
