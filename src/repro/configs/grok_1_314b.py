"""grok-1-314b [moe] — 64L, 8 experts top-2. [hf:xai-org/grok-1]"""
import dataclasses

from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    arch_type="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32_768,
    vocab_size=131_072,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32_768),
    param_dtype="bfloat16",       # 314B params: fp32 replica would not fit 256 v5e
    mom_dtype="bfloat16",
    source="hf:xai-org/grok-1",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=256),
        param_dtype="float32", mom_dtype="float32")
