"""phi4-mini-3.8b [dense] — RoPE SwiGLU GQA. [arXiv:2412.08905]"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi4-mini-3.8b",
    arch_type="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200_064,
    source="arXiv:2412.08905 (Phi-4-mini)",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512)
