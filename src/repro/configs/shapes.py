"""The four assigned input shapes (public-pool assignment)."""
from repro.configs.base import INPUT_SHAPES, InputShape

__all__ = ["INPUT_SHAPES", "InputShape"]
