"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free. [arXiv:2405.21060]"""
import dataclasses

from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,                  # attention-free
    num_kv_heads=0,
    d_ff=0,                       # no separate FFN (SSD block is the mixer)
    vocab_size=50_280,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk=256, conv_width=4),
    source="arXiv:2405.21060 (Mamba-2 2.7B)",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=2, d_model=128, vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, chunk=32, conv_width=4))
