"""Config registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import (ArchConfig, HybridConfig, InputShape,
                                INPUT_SHAPES, MoEConfig, SSMConfig,
                                TrainConfig)

# arch-id -> module name
ARCHS = {
    "whisper-base": "whisper_base",
    "grok-1-314b": "grok_1_314b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "qwen2-7b": "qwen2_7b",
    "llama3-405b": "llama3_405b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "mamba2-2.7b": "mamba2_2_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "llama-3.2-vision-90b": "llama_3_2_vision_90b",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> ArchConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ArchConfig:
    return _module(arch).smoke_config()


def list_archs():
    return sorted(ARCHS)


__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "HybridConfig",
           "InputShape", "INPUT_SHAPES", "TrainConfig",
           "get_config", "get_smoke_config", "list_archs", "ARCHS"]
