"""Architecture / run configuration for Omnivore-JAX.

Every assigned architecture gets one module in ``repro/configs/<id>.py``
exporting ``CONFIG`` (the full published config) and ``smoke_config()``
(a reduced same-family variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 SSD block parameters."""
    state_dim: int = 128          # N
    head_dim: int = 64            # P
    expand: int = 2               # d_inner = expand * d_model
    chunk: int = 256              # SSD chunk length
    conv_width: int = 4           # short depthwise causal conv


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """RecurrentGemma / Griffin: repeating (recurrent, recurrent, local-attn)."""
    pattern: Tuple[str, ...] = ("rec", "rec", "attn")
    d_rnn: Optional[int] = None   # RG-LRU width (defaults to d_model)
    local_window: int = 2048
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # variants
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "swiglu"           # swiglu | gelu
    sliding_window: Optional[int] = None   # set for sub-quadratic attention variant
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    # encoder-decoder (whisper): encoder layers; frontend supplies embeddings
    encoder_layers: int = 0
    encoder_seq: int = 1500       # whisper-base audio frames after conv frontend (stub)
    # vlm: cross-attention to image patch embeddings every k-th layer
    cross_attn_every: int = 0
    num_image_tokens: int = 1024  # patch embeddings from stubbed vision tower
    # numerics / memory
    param_dtype: str = "float32"
    mom_dtype: str = "float32"    # momentum buffer dtype (bf16 => ZeRO-ish footprint)
    compute_dtype: str = "bfloat16"
    remat: bool = True
    # citation for the config values
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    def dtype(self, which: str):
        return jnp.dtype({"param": self.param_dtype,
                          "mom": self.mom_dtype,
                          "compute": self.compute_dtype}[which])


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Execution-strategy knobs: the paper's tradeoff space."""
    num_groups: int = 1           # g: compute groups (degree of asynchrony); S = g-1
    learning_rate: float = 0.01   # eta
    momentum: float = 0.9         # mu (explicit)
    weight_decay: float = 0.0     # lambda
    grad_accum: int = 1           # microbatch accumulation steps
    sync_head: bool = True        # paper's "merged FC": head params update synchronously
    remat_policy: str = "full"    # full | none | dots
