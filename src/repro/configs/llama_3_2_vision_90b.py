"""llama-3.2-vision-90b [vlm] — cross-attn image layers every 5th layer; the
vision tower (ViT + projector) is a STUB: input_specs supplies precomputed
patch embeddings. [hf:meta-llama/Llama-3.2-11B-Vision, scaled per 90B card]"""
import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=128_256,
    rope_theta=500_000.0,
    cross_attn_every=5,           # 20 cross-attn layers in 100
    num_image_tokens=1024,        # stubbed ViT output tokens
    param_dtype="bfloat16",
    mom_dtype="bfloat16",
    source="hf:meta-llama/Llama-3.2-11B-Vision (90B scaling)",
)


def smoke_config() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, num_layers=5, d_model=128, num_heads=4, num_kv_heads=2,
        head_dim=32, d_ff=256, vocab_size=512, cross_attn_every=5,
        num_image_tokens=16, param_dtype="float32", mom_dtype="float32")
