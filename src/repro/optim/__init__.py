from repro.optim.closed_form import (GroupedCoeffs, grouped_coeffs,
                                     head_coeffs)
from repro.optim.sgd import init_momentum, sgd_update

__all__ = ["GroupedCoeffs", "grouped_coeffs", "head_coeffs", "init_momentum",
           "sgd_update"]
