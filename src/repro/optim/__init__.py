from repro.optim.sgd import init_momentum, sgd_update

__all__ = ["init_momentum", "sgd_update"]
