"""Closed form of g sequential momentum-SGD sub-steps on round-start
gradients (the grouped execution strategy, paper Fig. 17(b)).

Each sub-step i of a round applies paper eq. (3)-(4) with gradient g_i that
was evaluated at the *round-start* parameters, so the gradients are
constants of the recurrence and only the weight-decay term couples to the
evolving parameters:

    V_{i+1} = mu * V_i - eta * (g_i + lambda * W_i)
    W_{i+1} = W_i + V_{i+1}

which is the 2x2 linear recurrence

    [W_{i+1}]   [1 - eta*lambda   mu] [W_i]   [-eta]
    [V_{i+1}] = [   -eta*lambda   mu] [V_i] + [-eta] * g_i

Unrolling g steps (the algebra of "Asynchrony begets Momentum",
arXiv:1605.09774) gives one fused update over the stacked gradients:

    [W_g]       [W_0]   sum_i  [a_i]
    [V_g] = A^g [V_0] +        [b_i] * g_i,   [a_i; b_i] = A^{g-1-i} b

With lambda = 0 this is the familiar  W += sum_i a_i g_i,
V = mu^g V + sum_i b_i g_i  with a_i, b_i polynomials in mu. All
coefficients depend only on (g, eta, mu, lambda) — static hyperparameters —
so they are computed here once in float64 and baked into the compiled
update as constants. See docs/fused_update.md for the full derivation.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np


def _weight_scales(num_groups: int,
                   group_weights: Optional[Sequence[float]]):
    """Per-group gradient scales ``g * w_i / sum(w)``.

    Weighted grouped averaging (heterogeneous batch shares, see
    ``cluster.allocator``): group i's gradient enters every update scaled
    so that the round's total step matches a batch-share-weighted average.
    Uniform weights give scales of exactly 1.0 — a bitwise no-op — so the
    weighted path reduces to the unweighted one.
    """
    if group_weights is None:
        return None
    if len(group_weights) != num_groups:
        raise ValueError(f"need {num_groups} group weights, got "
                         f"{len(group_weights)}")
    w = [float(x) for x in group_weights]
    if any(x < 0.0 for x in w) or sum(w) <= 0.0:
        raise ValueError("group weights must be >= 0 with positive sum")
    s = sum(w)
    return [num_groups * x / s for x in w]


@dataclasses.dataclass(frozen=True)
class GroupedCoeffs:
    """Scalar coefficients of the fused g-sub-step update.

    W_new = cww*W + cwv*V + sum_i a[i]*g_i
    V_new = cvw*W + cvv*V + sum_i b[i]*g_i

    Frozen + tuple-valued so instances are hashable (usable as jit static
    arguments).
    """
    a: tuple            # per-group W coefficients, len g
    b: tuple            # per-group V coefficients, len g
    cww: float
    cwv: float
    cvw: float
    cvv: float

    @property
    def num_groups(self) -> int:
        return len(self.a)


def grouped_coeffs(num_groups: int, *, lr: float, momentum: float = 0.0,
                   weight_decay: float = 0.0,
                   group_weights: Optional[Sequence[float]] = None
                   ) -> GroupedCoeffs:
    """Coefficients of g sequential backbone sub-steps (staleness 0..g-1).

    a[i], b[i] = A^{g-1-i} @ (-eta, -eta); (cww..cvv) = A^g. Group i's
    gradient lands i updates stale, so it passes through g-1-i further
    applications of A — exactly the sequential scan, collapsed.

    ``group_weights`` (unequal batch shares): sub-step i's gradient is
    scaled by ``g * w_i / sum(w)``, i.e. its input vector becomes
    ``scale_i * (-eta, -eta)`` — linear, so only a[i], b[i] change.
    """
    if num_groups < 1:
        raise ValueError(f"num_groups must be >= 1, got {num_groups}")
    scales = _weight_scales(num_groups, group_weights)
    A = np.array([[1.0 - lr * weight_decay, momentum],
                  [-lr * weight_decay, momentum]], dtype=np.float64)
    bvec = np.array([-lr, -lr], dtype=np.float64)
    a = np.zeros(num_groups, dtype=np.float64)
    b = np.zeros(num_groups, dtype=np.float64)
    M = np.eye(2, dtype=np.float64)            # A^k, k = g-1-i
    for k in range(num_groups):
        i = num_groups - 1 - k
        a[i], b[i] = M @ bvec
        if scales is not None:
            a[i] *= scales[i]
            b[i] *= scales[i]
        M = A @ M
    return GroupedCoeffs(a=tuple(a.tolist()), b=tuple(b.tolist()),
                         cww=float(M[0, 0]), cwv=float(M[0, 1]),
                         cvw=float(M[1, 0]), cvv=float(M[1, 1]))


def head_coeffs(num_groups: int, *, lr: float, momentum: float = 0.0,
                weight_decay: float = 0.0,
                group_weights: Optional[Sequence[float]] = None
                ) -> GroupedCoeffs:
    """Merged-FC head: ONE zero-staleness update with the group-averaged
    gradient per round. Same fused form — a single application of A with
    the input vector split 1/g (or the normalized ``group_weights``)
    across the stacked gradients."""
    one = grouped_coeffs(1, lr=lr, momentum=momentum,
                         weight_decay=weight_decay)
    if group_weights is None:
        shares = [1.0 / num_groups] * num_groups
    else:
        # _weight_scales validates; scale_i / g = w_i / sum(w)
        shares = [s / num_groups
                  for s in _weight_scales(num_groups, group_weights)]
    return GroupedCoeffs(a=tuple(one.a[0] * s for s in shares),
                         b=tuple(one.b[0] * s for s in shares),
                         cww=one.cww, cwv=one.cwv, cvw=one.cvw, cvv=one.cvv)
