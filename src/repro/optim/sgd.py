"""SGD with momentum exactly as paper eq. (3)-(4):

    V <- mu * V - eta * (grad + lambda * W)
    W <- W + V

Momentum buffers may live in a reduced dtype (ZeRO-style footprint control
for the very large assigned archs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_momentum(params, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def sgd_update(params, grads, momentum_buf, *, lr, momentum=0.0,
               weight_decay=0.0):
    """One paper-eq-(3)/(4) update in a single tree traversal.
    Returns (new_params, new_momentum)."""
    flat_p, tree = jax.tree.flatten(params)
    # flatten_up_to raises on grads/momentum structure mismatch (a bare
    # zip would silently truncate and mis-pair leaves)
    flat_g = tree.flatten_up_to(grads)
    flat_v = tree.flatten_up_to(momentum_buf)
    new_p, new_v = [], []
    for p, g, v in zip(flat_p, flat_g, flat_v):
        g32 = g.astype(jnp.float32)
        if weight_decay:
            g32 = g32 + weight_decay * p.astype(jnp.float32)
        v_new = momentum * v.astype(jnp.float32) - lr * g32
        p_new = p.astype(jnp.float32) + v_new
        new_p.append(p_new.astype(p.dtype))
        new_v.append(v_new.astype(v.dtype))
    return tree.unflatten(new_p), tree.unflatten(new_v)
