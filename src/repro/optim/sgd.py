"""SGD with momentum exactly as paper eq. (3)-(4):

    V <- mu * V - eta * (grad + lambda * W)
    W <- W + V

Momentum buffers may live in a reduced dtype (ZeRO-style footprint control
for the very large assigned archs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_momentum(params, dtype=None):
    return jax.tree.map(
        lambda p: jnp.zeros_like(p, dtype=dtype or p.dtype), params)


def sgd_update(params, grads, momentum_buf, *, lr, momentum=0.0,
               weight_decay=0.0):
    """One paper-eq-(3)/(4) update. Returns (new_params, new_momentum)."""
    def upd(p, g, v):
        g32 = g.astype(jnp.float32)
        if weight_decay:
            g32 = g32 + weight_decay * p.astype(jnp.float32)
        v_new = momentum * v.astype(jnp.float32) - lr * g32
        p_new = p.astype(jnp.float32) + v_new
        return p_new.astype(p.dtype), v_new.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, momentum_buf)
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mom = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return new_params, new_mom
