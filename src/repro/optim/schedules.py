"""Learning-rate schedules (paper App. F-G compares Omnivore's epoch-wise
re-tuning against CaffeNet's fixed step decay)."""
from __future__ import annotations

import math
from typing import Callable


def constant(lr: float) -> Callable[[int], float]:
    return lambda step: lr


def step_decay(lr: float, *, drop: float = 10.0,
               every: int = 100_000) -> Callable[[int], float]:
    """CaffeNet default: divide by `drop` every `every` iterations."""
    return lambda step: lr / (drop ** (step // every))


def cosine(lr: float, *, total_steps: int,
           final_frac: float = 0.1) -> Callable[[int], float]:
    def f(step):
        t = min(step / max(total_steps, 1), 1.0)
        return lr * (final_frac + (1 - final_frac)
                     * 0.5 * (1 + math.cos(math.pi * t)))
    return f


def warmup_then(schedule: Callable[[int], float],
                warmup_steps: int) -> Callable[[int], float]:
    def f(step):
        if step < warmup_steps:
            return schedule(warmup_steps) * (step + 1) / warmup_steps
        return schedule(step)
    return f
