"""Serving-mode planner: split heterogeneous devices into prefill vs
decode pools against a p50/p99 latency SLO.

The paper's planner (``cluster.planner``) searches ``T(g, alloc) =
HE x SE`` — raw speed times statistical usefulness. Serving transposes
the same tradeoff: raw speed becomes token throughput, statistical
usefulness becomes the fraction of tokens delivered inside the latency
SLO, and their product is **goodput** (``ServeReport.goodput``). The
search axis is no longer g but the pool split: prefill-heavy pools admit
fast but starve decode (queue tail explodes); decode-heavy pools decode
fast but make requests wait for first token.

``simulate_serving`` is the discrete-event validator — the serving
extension of ``cluster.sim.simulate_hetero``: FCFS prefill workers (one
request at a time, service time = prompt/rate) feeding a synchronous
continuous-batching decode pool whose step time grows with occupancy
(``(c0 + occupancy) / pooled-rate`` — a fixed dispatch overhead in
token-equivalents plus one token per live lane, matching how the real
``ContinuousServer`` amortizes a step across lanes). Devices stay black
boxes: only ``tok_rate`` (tokens/s, the measured ``throughput`` field or
a FLOPs-proportional fallback) enters the model.

``plan_serving`` sweeps every split size under both assignment policies
(fastest devices to prefill vs to decode), simulates each, and keeps the
plan with the best goodput at the SLO — p99 breaking ties.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.cluster.devices import DeviceSpec

#: tokens/s assigned to a device with neither a measurement nor FLOPs.
_FALLBACK_RATE = 1.0
#: FLOPs-per-token scale for the roofline fallback (arbitrary but fixed;
#: only *relative* rates matter to the split search).
_FLOPS_PER_TOKEN = 1e9


def tok_rate(dev: DeviceSpec) -> float:
    """Black-box serving rate (tokens/s) for one device."""
    if dev.throughput is not None:
        return float(dev.throughput)
    if dev.peak_flops > 0:
        return dev.peak_flops / _FLOPS_PER_TOKEN
    return _FALLBACK_RATE


@dataclasses.dataclass(frozen=True)
class ServingSimResult:
    """Outcome of one simulated trace against one pool split."""
    latencies: np.ndarray        # (R,) finish - arrival, seconds
    queue_waits: np.ndarray      # (R,) wait before a prefill worker
    prefill_times: np.ndarray    # (R,)
    decode_times: np.ndarray     # (R,)
    gen_counts: np.ndarray       # (R,) tokens generated per request
    makespan: float
    occupancy_mean: float

    def percentile(self, q: float) -> float:
        return float(np.percentile(self.latencies, q))

    @property
    def throughput(self) -> float:
        return float(self.gen_counts.sum()) / max(self.makespan, 1e-12)

    def goodput(self, slo_s: float) -> float:
        ok = self.latencies <= slo_s
        return float(self.gen_counts[ok].sum()) / max(self.makespan, 1e-12)


def simulate_serving(*, arrivals: Sequence[float],
                     prompt_lens: Sequence[int], gen_lens: Sequence[int],
                     prefill_rates: Sequence[float],
                     decode_rates: Sequence[float],
                     slots: int = 8, step_overhead_tokens: float = 1.0
                     ) -> ServingSimResult:
    """Discrete-event run of one trace through a prefill pool + a
    continuous-batching decode pool (module docstring).

    ``prefill_rates`` / ``decode_rates``: tokens/s per pool member.
    Decode is synchronous-stepped: a step at occupancy ``o`` takes
    ``(step_overhead_tokens + o) / sum(decode_rates)`` seconds and
    advances every live lane one token; lanes join at step boundaries
    and retire the step their generation completes.
    """
    R = len(arrivals)
    if not (len(prompt_lens) == len(gen_lens) == R):
        raise ValueError("arrivals/prompt_lens/gen_lens must align")
    if not prefill_rates or not decode_rates:
        raise ValueError("both pools need at least one device")
    if min(gen_lens) < 1:
        raise ValueError("every request must generate at least one token")
    if slots < 1:
        raise ValueError("need at least one decode slot")
    pool_rate = float(sum(decode_rates))

    # -- prefill: FCFS over parallel workers --------------------------------
    # (worker_free_time, seq, rate); arrival order is FCFS order.
    workers = [(0.0, i, float(r)) for i, r in enumerate(prefill_rates)]
    heapq.heapify(workers)
    order = np.argsort(np.asarray(arrivals, dtype=np.float64), kind="stable")
    ready = []                                    # (ready_time, seq, req idx)
    q_wait = np.zeros(R)
    pf_time = np.zeros(R)
    for seq, i in enumerate(order):
        free_t, wid, rate = heapq.heappop(workers)
        start = max(float(arrivals[i]), free_t)
        dur = float(prompt_lens[i]) / rate
        heapq.heappush(workers, (start + dur, wid, rate))
        q_wait[i] = start - float(arrivals[i])
        pf_time[i] = dur
        heapq.heappush(ready, (start + dur, seq, int(i)))

    # -- decode: synchronous continuous batching ----------------------------
    finish = np.zeros(R)
    dec_start = np.zeros(R)
    t = 0.0
    lanes: List[Tuple[int, int]] = []             # (req idx, tokens left)
    occ_num = 0.0
    occ_den = 0.0
    while ready or lanes:
        if not lanes:                             # idle: jump to next ready
            t = max(t, ready[0][0])
        while ready and len(lanes) < slots and ready[0][0] <= t:
            _, _, i = heapq.heappop(ready)
            dec_start[i] = t
            lanes.append((i, int(gen_lens[i])))
        occ = len(lanes)
        dt = (step_overhead_tokens + occ) / pool_rate
        t += dt
        occ_num += occ * dt
        occ_den += dt
        nxt = []
        for i, left in lanes:
            if left - 1 == 0:
                finish[i] = t
            else:
                nxt.append((i, left - 1))
        lanes = nxt

    lat = finish - np.asarray(arrivals, dtype=np.float64)
    return ServingSimResult(
        latencies=lat, queue_waits=q_wait, prefill_times=pf_time,
        decode_times=finish - dec_start,
        gen_counts=np.asarray(gen_lens, dtype=np.int64),
        makespan=float(finish.max(initial=0.0)),
        occupancy_mean=occ_num / occ_den if occ_den else 0.0)


@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """One scored pool split."""
    prefill_devices: Tuple[DeviceSpec, ...]
    decode_devices: Tuple[DeviceSpec, ...]
    policy: str                  # "fast-prefill" | "fast-decode"
    slo_p99_s: float
    result: ServingSimResult
    goodput: float               # tokens/s inside the SLO
    meets_slo: bool              # p99 <= slo_p99_s

    def describe(self) -> str:
        def mix(devs):
            kinds = [d.kind for d in devs]
            return "+".join(f"{kinds.count(k)}{k}" for k in sorted(set(kinds)))
        r = self.result
        return (f"serving plan [{self.policy}]: "
                f"prefill={mix(self.prefill_devices)} "
                f"decode={mix(self.decode_devices)} "
                f"goodput={self.goodput:.1f} tok/s "
                f"p50={r.percentile(50) * 1e3:.1f}ms "
                f"p99={r.percentile(99) * 1e3:.1f}ms "
                f"(slo {self.slo_p99_s * 1e3:.0f}ms "
                f"{'met' if self.meets_slo else 'MISSED'}) "
                f"occ={r.occupancy_mean:.2f}")


def plan_serving(devices: Sequence[DeviceSpec], *,
                 arrivals: Sequence[float], prompt_lens: Sequence[int],
                 gen_lens: Sequence[int], slo_p99_s: float,
                 slots: int = 8, step_overhead_tokens: float = 1.0
                 ) -> ServingPlan:
    """Search every prefill/decode split of ``devices`` (both directions
    of the sorted-by-rate assignment), simulate the trace through each,
    and return the plan with the highest goodput at the p99 SLO — p99
    latency breaking ties. Raises when fewer than two devices (each pool
    needs one)."""
    if len(devices) < 2:
        raise ValueError("plan_serving needs >= 2 devices (one per pool)")
    ranked = sorted(devices, key=tok_rate, reverse=True)
    best: Optional[ServingPlan] = None
    for k in range(1, len(ranked)):               # k = prefill pool size
        for policy in ("fast-prefill", "fast-decode"):
            if policy == "fast-prefill":
                pf, dec = ranked[:k], ranked[k:]
            else:
                dec, pf = ranked[:len(ranked) - k], ranked[len(ranked) - k:]
            res = simulate_serving(
                arrivals=arrivals, prompt_lens=prompt_lens,
                gen_lens=gen_lens,
                prefill_rates=[tok_rate(d) for d in pf],
                decode_rates=[tok_rate(d) for d in dec],
                slots=slots, step_overhead_tokens=step_overhead_tokens)
            plan = ServingPlan(
                prefill_devices=tuple(pf), decode_devices=tuple(dec),
                policy=policy, slo_p99_s=slo_p99_s, result=res,
                goodput=res.goodput(slo_p99_s),
                meets_slo=res.percentile(99) <= slo_p99_s)
            if (best is None or plan.goodput > best.goodput
                    or (plan.goodput == best.goodput
                        and plan.result.percentile(99)
                        < best.result.percentile(99))):
                best = plan
    assert best is not None
    return best
