"""Heterogeneous cluster subsystem (paper §V's third contribution).

Black-box device profiles (``devices``), throughput-proportional group
allocation (``allocator``), heterogeneous queue simulation (``sim``),
the time-to-convergence planner ``T(g, alloc) = HE x SE`` (``planner``),
and the serving-mode planner splitting devices into prefill vs decode
pools against a latency SLO (``serving``).
"""
from repro.cluster.allocator import Allocation, allocate, rebalance
from repro.cluster.devices import (DeviceSpec, WorkloadCost, get_device,
                                   list_devices, parse_cluster_spec,
                                   profile_device, profiled_spec,
                                   register_device, spec_from_telemetry)
from repro.cluster.planner import (Plan, best_allocation,
                                   hetero_time_per_iteration,
                                   mp_collective_time, mp_feasible,
                                   plan_for_g, plan_for_g_mp)
from repro.cluster.serving import (ServingPlan, ServingSimResult,
                                   plan_serving, simulate_serving, tok_rate)
from repro.cluster.sim import simulate_hetero

__all__ = [
    "Allocation", "allocate", "rebalance",
    "DeviceSpec", "WorkloadCost", "get_device", "list_devices",
    "parse_cluster_spec", "profile_device", "profiled_spec",
    "register_device", "spec_from_telemetry",
    "Plan", "best_allocation", "hetero_time_per_iteration",
    "mp_collective_time", "mp_feasible", "plan_for_g", "plan_for_g_mp",
    "ServingPlan", "ServingSimResult", "plan_serving", "simulate_serving",
    "tok_rate",
    "simulate_hetero",
]
