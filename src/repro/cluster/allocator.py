"""Heterogeneous group allocation + throughput-proportional batch shares.

Partitions N black-box devices (``cluster.devices.DeviceSpec``) into g
compute groups and apportions the global batch across groups in proportion
to group throughput, so every group's conv phase finishes at (predicted)
the same time — the load-balancing idea of OmniLearn (PAPERS.md) applied to
Omnivore's group axis.

- ``allocate``: LPT-style greedy packing — devices sorted by descending
  throughput, each placed in the currently slowest group — which both
  guarantees no empty group (g <= N) and near-equalizes group throughputs.
- ``rebalance``: measurement-driven correction — given observed per-group
  step times, re-estimates group throughputs as share/time and re-apportions
  the batch so predicted per-group step times equalize (OmniLearn's dynamic
  batch sizing).

The resulting integer ``microbatches`` are consumable by
``compute_groups.group_batch_split(batch, g, sizes=...)`` and the
``weights`` by ``async_sgd.make_grouped_train_step(group_weights=...)``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from repro.cluster.devices import DeviceSpec, WorkloadCost


@dataclasses.dataclass(frozen=True)
class Allocation:
    """g groups over a fixed device tuple + the batch apportionment."""
    devices: Tuple[DeviceSpec, ...]
    groups: Tuple[Tuple[int, ...], ...]     # device indices per group
    throughputs: Tuple[float, ...]          # examples/s per group
    microbatches: Tuple[int, ...]           # per-group batch share, sums to B
    global_batch: int

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    @property
    def weights(self) -> Tuple[float, ...]:
        """Gradient-averaging weights: the batch share of each group."""
        return tuple(b / self.global_batch for b in self.microbatches)

    def group_devices(self, i: int) -> Tuple[DeviceSpec, ...]:
        return tuple(self.devices[j] for j in self.groups[i])


def _apportion(total: int, weights: Sequence[float], minimum: int = 1
               ) -> Tuple[int, ...]:
    """Largest-remainder apportionment of ``total`` ∝ ``weights``, each
    share >= ``minimum``."""
    n = len(weights)
    if total < n * minimum:
        raise ValueError(f"batch {total} too small for {n} groups "
                         f"(minimum {minimum} each)")
    wsum = float(sum(weights))
    if wsum <= 0.0:
        raise ValueError("weights must have positive sum")
    spare = total - n * minimum
    ideal = [spare * w / wsum for w in weights]
    shares = [int(x) for x in ideal]
    rem = spare - sum(shares)
    # hand the remaining units to the largest fractional parts
    order = sorted(range(n), key=lambda i: ideal[i] - shares[i], reverse=True)
    for i in order[:rem]:
        shares[i] += 1
    return tuple(minimum + s for s in shares)


def allocate(devices: Sequence[DeviceSpec], g: int, global_batch: int, *,
             cost: Optional[WorkloadCost] = None) -> Allocation:
    """Pack ``devices`` into ``g`` groups (LPT greedy) and split the batch
    proportional to group throughput."""
    n = len(devices)
    if not 1 <= g <= n:
        raise ValueError(f"g={g} must be in 1..N={n}")
    thr = [d.predict_throughput(cost) for d in devices]
    order = sorted(range(n), key=lambda i: thr[i], reverse=True)
    groups = [[] for _ in range(g)]
    gthr = [0.0] * g
    for i in order:
        # LPT: place the next-fastest device in the slowest group; the first
        # g placements seed every group, so none is ever empty
        j = min(range(g), key=lambda k: (gthr[k], len(groups[k])))
        groups[j].append(i)
        gthr[j] += thr[i]
    micro = _apportion(global_batch, gthr)
    return Allocation(devices=tuple(devices),
                      groups=tuple(tuple(gr) for gr in groups),
                      throughputs=tuple(gthr),
                      microbatches=micro,
                      global_batch=global_batch)


def rebalance(alloc: Allocation, measured_step_times: Sequence[float]
              ) -> Allocation:
    """Re-apportion the batch from *observed* per-group step times.

    The black-box group throughput becomes share/time; re-running the
    proportional apportionment then equalizes predicted step times — the
    fixed point is reached when every group takes the same wall time per
    round (OmniLearn's balance condition).
    """
    if len(measured_step_times) != alloc.num_groups:
        raise ValueError(f"need {alloc.num_groups} measured times, got "
                         f"{len(measured_step_times)}")
    if any(t <= 0.0 for t in measured_step_times):
        raise ValueError("measured step times must be positive")
    new_thr = tuple(b / t for b, t in zip(alloc.microbatches,
                                          measured_step_times))
    micro = _apportion(alloc.global_batch, new_thr)
    return dataclasses.replace(alloc, throughputs=new_thr,
                               microbatches=micro)
