"""Heterogeneous discrete-event simulation of the server architecture.

Generalizes ``core.queue_sim.simulate`` (g identical compute groups feeding
one serial merged-FC server) to *per-group* conv service times, so
staleness and time-per-iteration can be validated under heterogeneous
allocations and stragglers: group i's conv phase has mean ``t_conv[i]``
(its microbatch / group throughput, see ``cluster.planner``), optionally
scaled by a per-group straggler factor.

The event loop and RNG consumption order mirror ``queue_sim.simulate``
statement-for-statement, so with identical group means (and the same seed)
the result is bit-identical to the homogeneous simulator — the reduction
property the tier-1 tests pin down.
"""
from __future__ import annotations

import heapq
from typing import Optional, Sequence

import numpy as np

from repro.core.queue_sim import SimResult


def simulate_hetero(*, t_conv: Sequence[float], t_fc: float,
                    iters: int = 2000, exponential: bool = True,
                    seed: int = 0, cv: Optional[float] = None,
                    slowdown: Optional[Sequence[float]] = None,
                    return_trace: bool = False):
    """Event loop with per-group conv means ``t_conv`` (length g).

    ``slowdown``, when given, multiplies each group's mean — a straggler
    model (e.g. ``[1, 1, 3, 1]`` makes group 2 a 3x straggler). Staleness
    of an update is the number of model updates between the group's read
    and its write, exactly as in the homogeneous simulator.

    ``return_trace=True`` additionally returns the per-commit
    ``repro.exec.trace.EventTrace`` for the replay engine; recording does
    not consume RNG, so the ``SimResult`` is bit-identical either way.
    """
    t_conv = [float(t) for t in t_conv]
    g = len(t_conv)
    if g < 1:
        raise ValueError("need at least one group")
    if slowdown is not None:
        if len(slowdown) != g:
            raise ValueError(f"slowdown needs length g={g}")
        t_conv = [t * float(s) for t, s in zip(t_conv, slowdown)]
    rng = np.random.default_rng(seed)

    def dur(mean):
        if exponential:
            return rng.exponential(mean)
        if cv:  # lognormal with given coefficient of variation
            sigma = np.sqrt(np.log(1 + cv ** 2))
            return rng.lognormal(np.log(mean) - sigma ** 2 / 2, sigma)
        return mean

    version = 0
    read_version = {i: 0 for i in range(g)}
    staleness = []
    commits = []  # (group, read_version, time) per fc_done
    fc_busy_until = 0.0
    done_time = None
    events = []  # (time, seq, kind, group)
    seq = 0
    for i in range(g):
        heapq.heappush(events, (dur(t_conv[i]), seq, "conv_done", i))
        seq += 1

    completed = 0
    while completed < iters and events:
        t, _, kind, grp = heapq.heappop(events)
        if kind == "conv_done":
            start = max(t, fc_busy_until)
            fin = start + dur(t_fc)
            fc_busy_until = fin
            heapq.heappush(events, (fin, seq, "fc_done", grp))
            seq += 1
        else:  # fc_done: model update commits
            staleness.append(version - read_version[grp])
            commits.append((grp, read_version[grp], t))
            version += 1
            completed += 1
            done_time = t
            read_version[grp] = version     # group re-reads fresh model
            heapq.heappush(events, (t + dur(t_conv[grp]), seq, "conv_done", grp))
            seq += 1

    st = np.asarray(staleness[iters // 10:])  # drop warmup
    result = SimResult(time_per_iteration=done_time / completed,
                       iterations=completed,
                       mean_staleness=float(st.mean()),
                       staleness_hist=np.bincount(st, minlength=2 * g))
    if not return_trace:
        return result
    from repro.exec.trace import EventTrace  # local: avoids import cycles
    grp_a, rv_a, t_a = (np.asarray(c) for c in zip(*commits))
    return result, EventTrace(num_groups=g, group=grp_a, read_version=rv_a,
                              commit_time=t_a)
