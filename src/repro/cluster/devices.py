"""Black-box device profiles — the heterogeneity premise of paper §V.

The paper's predictive model treats every node as a *black box* with a
measured throughput: the optimizer never inspects what the device is, only
how many examples per second it pushes through the actual training step.
This module provides both halves of that premise:

- ``DeviceSpec``: a named roofline profile (CPU / GPU / TPU) that can
  *predict* throughput for a workload cost when no measurement exists
  (planning before the cluster is up), and
- ``profile_device``: the black-box probe that *measures* a jitted step on
  the device actually running, returning a spec whose ``throughput`` field
  overrides the roofline.

Specs are consumed by ``cluster.allocator`` (group packing + batch shares)
and ``cluster.planner`` (time-to-convergence search).
"""
from __future__ import annotations

import dataclasses
import re
import time
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class WorkloadCost:
    """Per-example cost of one training step + the collective payload."""
    flops_per_example: float     # fwd+bwd FLOPs for ONE example
    bytes_per_example: float     # HBM/DRAM traffic for ONE example
    grad_bytes: float = 0.0      # gradient payload reduced within a group
    state_bytes: float = 0.0     # resident params+optimizer bytes per model
    #                              replica (the mp axis shards this: a worker
    #                              of mp devices holds state_bytes/mp each)


@dataclasses.dataclass(frozen=True)
class DeviceSpec:
    """One device, roofline profile + optional black-box measurement.

    ``throughput`` (examples/s), when set, is a *measurement* and takes
    precedence over the roofline prediction — the paper's "each node is a
    black box" contract.
    """
    name: str
    kind: str                    # "cpu" | "gpu" | "tpu"
    peak_flops: float            # FLOP/s
    mem_bw: float                # bytes/s
    net_bw: float                # bytes/s to the reduction / parameter server
    throughput: Optional[float] = None   # measured examples/s (black box)
    mem_bytes: Optional[float] = None    # device memory capacity; None =
    #                                      unconstrained (planner memory-
    #                                      feasibility checks skip it)

    def predict_throughput(self, cost: Optional[WorkloadCost] = None) -> float:
        """Examples/s: the measurement if present, else the roofline."""
        if self.throughput is not None:
            return self.throughput
        if cost is None:
            raise ValueError(
                f"device {self.name!r} has no measured throughput; "
                "pass a WorkloadCost for the roofline prediction")
        t = max(cost.flops_per_example / self.peak_flops,
                cost.bytes_per_example / self.mem_bw)
        if t <= 0.0:
            raise ValueError("WorkloadCost must be positive")
        return 1.0 / t


# ---------------------------------------------------------------------------
# Registry. Constants: EC2 c4/g2 are the paper's CPU/GPU cluster nodes
# (§VI-A); titan-x its workstation GPU; tpu-v5e mirrors
# core.hardware_model.V5E so the homogeneous model and this subsystem agree.
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, DeviceSpec] = {}


def register_device(spec: DeviceSpec) -> DeviceSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get_device(name: str) -> DeviceSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown device {name!r}; known: {sorted(_REGISTRY)}") from None


def list_devices() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


register_device(DeviceSpec("cpu-c4.4xlarge", "cpu",
                           peak_flops=0.45e12, mem_bw=60e9, net_bw=1.25e9,
                           mem_bytes=30e9))
register_device(DeviceSpec("gpu-g2.2xlarge", "gpu",
                           peak_flops=2.4e12, mem_bw=160e9, net_bw=1.25e9,
                           mem_bytes=4e9))
register_device(DeviceSpec("gpu-titan-x", "gpu",
                           peak_flops=6.6e12, mem_bw=336e9, net_bw=1.25e9,
                           mem_bytes=12e9))
register_device(DeviceSpec("tpu-v5e", "tpu",
                           peak_flops=197e12, mem_bw=819e9, net_bw=50e9,
                           mem_bytes=16e9))


_SPEC_ITEM = re.compile(r"^(?:(\d+)x)?([A-Za-z0-9_.\-]+)$")


def parse_cluster_spec(spec: str) -> Tuple[DeviceSpec, ...]:
    """Parse ``"8xgpu-g2.2xlarge,8xcpu-c4.4xlarge"`` into device instances."""
    devices = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        m = _SPEC_ITEM.match(item)
        if not m:
            raise ValueError(f"bad cluster-spec item {item!r} "
                             "(expected [<count>x]<device-name>)")
        count = int(m.group(1) or 1)
        if count < 1:
            raise ValueError(f"bad device count in {item!r}")
        devices.extend([get_device(m.group(2))] * count)
    if not devices:
        raise ValueError(f"empty cluster spec {spec!r}")
    return tuple(devices)


# ---------------------------------------------------------------------------
# Black-box probe
# ---------------------------------------------------------------------------

def profile_device(step_fn: Callable, args: Sequence, *, batch_size: int,
                   warmup: int = 1, iters: int = 5) -> float:
    """Time the actual jitted training step and return examples/s.

    ``step_fn(*args)`` is run ``warmup`` untimed calls (absorbing jit
    compilation) then ``iters`` timed calls; the median wall time is the
    black-box service time. The probe never looks inside the step — that is
    the point.
    """
    if batch_size < 1:
        raise ValueError("batch_size must be >= 1")
    from repro.obs import spans
    with spans.span("cluster.profile_device", batch_size=batch_size,
                    warmup=warmup, iters=iters) as sp:
        for _ in range(warmup):
            jax.block_until_ready(step_fn(*args))
        times = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(step_fn(*args))
            times.append(time.perf_counter() - t0)
        times.sort()
        median = times[len(times) // 2]
        thr = batch_size / median
        sp.set(examples_per_s=thr)
    return thr


def profiled_spec(spec: DeviceSpec, step_fn: Callable, args: Sequence, *,
                  batch_size: int, warmup: int = 1, iters: int = 5
                  ) -> DeviceSpec:
    """Return ``spec`` with its black-box ``throughput`` field measured."""
    thr = profile_device(step_fn, args, batch_size=batch_size,
                         warmup=warmup, iters=iters)
    return dataclasses.replace(spec, throughput=thr)


def spec_from_telemetry(spec: DeviceSpec, telemetry, *, batch_size: int,
                        window: Optional[int] = None) -> DeviceSpec:
    """``spec`` with throughput taken from an execution engine's per-step
    telemetry (``repro.engine.timing.Telemetry``) — the planner-calibration
    path that needs no extra probe run: the training steps the engine
    already timed ARE the black-box measurement. ``window`` calibrates
    from only the most recent N steady steps (time-varying clusters —
    the online ``rebalance()`` hook; see also ``Telemetry.drift``)."""
    return dataclasses.replace(
        spec, throughput=telemetry.throughput(batch_size, window=window))
