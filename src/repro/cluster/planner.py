"""Time-to-convergence planner over heterogeneous allocations.

The paper's predictive model (§V, App E) picks an execution strategy by
minimizing  total time = HE x SE : seconds/iteration times iterations to
target. This module generalizes the HE half to heterogeneous groups and
composes it with the statistical model:

    T(g, alloc) = HE(g, alloc) * P_SE(g)

- ``group_conv_times``: per-group conv-phase service time from the
  allocation — microbatch / group throughput, overlapped (max) with the
  intra-group collective over the slowest link, mirroring
  ``hardware_model.t_conv``.
- ``hetero_time_per_iteration``: g heterogeneous groups feeding one serial
  merged-FC server. Each group cycles every ``t_i + t_fc`` when the server
  is free, so the aggregate update rate is ``sum_i 1/(t_i + t_fc)`` capped
  by the server rate ``1/t_fc``:

      HE = max( t_fc,  1 / sum_i 1/(t_i + t_fc) )

  With g identical groups this is exactly
  ``hardware_model.he_time_per_iteration``'s
  ``max(t_fc, (t_conv + t_fc)/g)``.
- ``best_allocation``: search over (g, alloc) — ``allocator.allocate`` for
  each candidate g, score by ``HE * predict_se_penalty(g, mu*)``, return
  the best ``Plan``. ``Plan.g`` seeds ``auto_optimizer.algorithm1``
  (its ``plan=`` argument) in place of the homogeneous
  ``smallest_saturating_g`` short-circuit.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple

from repro.cluster.allocator import Allocation, allocate
from repro.cluster.devices import DeviceSpec, WorkloadCost
from repro.core.stat_model import predict_se_penalty


@dataclasses.dataclass(frozen=True)
class Plan:
    """One point of the (g, alloc) search, fully scored."""
    g: int
    allocation: Allocation
    group_times: Tuple[float, ...]   # per-group conv service time, seconds
    t_iteration: float               # predicted HE seconds/iteration
    se_penalty: float                # P_SE(g), >= 1
    time_score: float                # t_iteration * se_penalty

    @property
    def weights(self) -> Tuple[float, ...]:
        return self.allocation.weights

    def describe(self) -> str:
        rows = []
        for i, (idxs, t) in enumerate(zip(self.allocation.groups,
                                          self.group_times)):
            kinds = [self.allocation.devices[j].kind for j in idxs]
            mix = "+".join(f"{kinds.count(k)}{k}" for k in sorted(set(kinds)))
            rows.append(f"  group {i}: {mix:12s} batch="
                        f"{self.allocation.microbatches[i]:4d} "
                        f"t_conv={t * 1e3:.2f}ms")
        return (f"plan g={self.g} t_iter={self.t_iteration * 1e3:.2f}ms "
                f"P_SE={self.se_penalty:.2f} "
                f"score={self.time_score * 1e3:.2f}ms\n" + "\n".join(rows))


def group_collective_time(devices: Sequence[DeviceSpec],
                          grad_bytes: float) -> float:
    """Ring reduce-scatter + all-gather within a group, paced by the
    slowest link (same form as ``hardware_model.collective_time``)."""
    k = len(devices)
    if k <= 1 or grad_bytes <= 0.0:
        return 0.0
    bw = min(d.net_bw for d in devices)
    return 2.0 * grad_bytes * (k - 1) / k / bw


def group_conv_times(alloc: Allocation,
                     cost: Optional[WorkloadCost] = None
                     ) -> Tuple[float, ...]:
    """Per-group conv-phase time: compute on the group's microbatch,
    overlapped (max) with its intra-group collective."""
    times = []
    grad_bytes = cost.grad_bytes if cost is not None else 0.0
    for i in range(alloc.num_groups):
        comp = alloc.microbatches[i] / alloc.throughputs[i]
        coll = group_collective_time(alloc.group_devices(i), grad_bytes)
        times.append(max(comp, coll))
    return tuple(times)


def hetero_time_per_iteration(group_times: Sequence[float],
                              t_fc: float) -> float:
    """HE seconds/iteration for heterogeneous groups + one serial FC server."""
    if not group_times:
        raise ValueError("need at least one group")
    rate = sum(1.0 / (t + t_fc) for t in group_times)
    return max(t_fc, 1.0 / rate)


def plan_for_g(devices: Sequence[DeviceSpec], g: int, *, global_batch: int,
               t_fc: float, cost: Optional[WorkloadCost] = None,
               mu_star_total: float = 0.9, se_sharpness: float = 4.0,
               se_penalties: Optional[Mapping[int, float]] = None) -> Plan:
    """Score one candidate g: allocate, predict HE, multiply by P_SE.

    ``se_penalties`` overrides the analytic SE model with *measured*
    penalties (``stat_model.measured_se_from_replay`` over replayed
    traces) for the g values it contains; others fall back to
    ``predict_se_penalty``.
    """
    alloc = allocate(devices, g, global_batch, cost=cost)
    times = group_conv_times(alloc, cost)
    t_iter = hetero_time_per_iteration(times, t_fc)
    if se_penalties is not None and g in se_penalties:
        pse = float(se_penalties[g])
    else:
        pse = predict_se_penalty(g, mu_star_total, sharpness=se_sharpness)
    return Plan(g=g, allocation=alloc, group_times=times, t_iteration=t_iter,
                se_penalty=pse, time_score=t_iter * pse)


def best_allocation(devices: Sequence[DeviceSpec], *, global_batch: int,
                    t_fc: float, cost: Optional[WorkloadCost] = None,
                    mu_star_total: float = 0.9, se_sharpness: float = 4.0,
                    g_candidates: Optional[Sequence[int]] = None,
                    se_penalties: Optional[Mapping[int, float]] = None
                    ) -> Plan:
    """Search (g, alloc) for the minimum predicted time-to-convergence.

    Default candidate set is every feasible g (1..min(N, global_batch) —
    each group needs a device and at least one example). Returns the best
    ``Plan``; ties break toward smaller g (less staleness for free).

    ``se_penalties`` (measured P_SE per g, from
    ``stat_model.measured_se_from_replay``) replaces the analytic SE
    penalty for the g values it covers — replay-calibrated planning.
    """
    n = len(devices)
    if g_candidates is None:
        g_candidates = range(1, min(n, global_batch) + 1)
    best: Optional[Plan] = None
    for g in g_candidates:
        if not 1 <= g <= min(n, global_batch):
            raise ValueError(f"candidate g={g} infeasible for N={n}, "
                             f"batch={global_batch}")
        plan = plan_for_g(devices, g, global_batch=global_batch, t_fc=t_fc,
                          cost=cost, mu_star_total=mu_star_total,
                          se_sharpness=se_sharpness,
                          se_penalties=se_penalties)
        if best is None or plan.time_score < best.time_score:
            best = plan
    return best
