"""Time-to-convergence planner over heterogeneous allocations.

The paper's predictive model (§V, App E) picks an execution strategy by
minimizing  total time = HE x SE : seconds/iteration times iterations to
target. This module generalizes the HE half to heterogeneous groups and
composes it with the statistical model:

    T(g, mp, alloc) = HE(g, mp, alloc) * P_SE(g)

The search is 2-D over (g, mp): g async compute groups times mp
model-parallel devices per worker (the engine's "mp" mesh axis,
``engine.spmd``). mp enters the HE half three ways:

- compute: the engine's mp sharding is storage-only — every device of a
  worker runs the full forward/backward on the worker's microbatch — so
  a group's effective data-parallel throughput divides by mp;
- collectives: the data/group gradient exchange carries ``grad_bytes/mp``
  (each device exchanges only its shard), while a new per-worker
  mp-collective gathers the full parameters from the mp shards every
  step (``mp_collective_time``);
- memory: a worker holds ``state_bytes / mp`` per device — the
  feasibility constraint (``mp_feasible``) that makes mp > 1 worth its
  throughput cost for models that do not fit one device
  (``DeviceSpec.mem_bytes``).

P_SE depends on g only: mp changes where bytes live, not the staleness
structure of the update.

- ``group_conv_times``: per-group conv-phase service time from the
  allocation — microbatch / group throughput, overlapped (max) with the
  intra-group collective over the slowest link, mirroring
  ``hardware_model.t_conv``.
- ``hetero_time_per_iteration``: g heterogeneous groups feeding one serial
  merged-FC server. Each group cycles every ``t_i + t_fc`` when the server
  is free, so the aggregate update rate is ``sum_i 1/(t_i + t_fc)`` capped
  by the server rate ``1/t_fc``:

      HE = max( t_fc,  1 / sum_i 1/(t_i + t_fc) )

  With g identical groups this is exactly
  ``hardware_model.he_time_per_iteration``'s
  ``max(t_fc, (t_conv + t_fc)/g)``.
- ``best_allocation``: search over (g, alloc) — ``allocator.allocate`` for
  each candidate g, score by ``HE * predict_se_penalty(g, mu*)``, return
  the best ``Plan``. ``Plan.g`` seeds ``auto_optimizer.algorithm1``
  (its ``plan=`` argument) in place of the homogeneous
  ``smallest_saturating_g`` short-circuit.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional, Sequence, Tuple

from repro.cluster.allocator import Allocation, allocate
from repro.cluster.devices import DeviceSpec, WorkloadCost
from repro.core.stat_model import predict_se_penalty


@dataclasses.dataclass(frozen=True)
class Plan:
    """One point of the (g, mp, alloc) search, fully scored."""
    g: int
    allocation: Allocation
    group_times: Tuple[float, ...]   # per-group conv service time, seconds
    t_iteration: float               # predicted HE seconds/iteration
    se_penalty: float                # P_SE(g), >= 1
    time_score: float                # t_iteration * se_penalty
    mp: int = 1                      # model-parallel devices per worker

    @property
    def weights(self) -> Tuple[float, ...]:
        return self.allocation.weights

    def describe(self) -> str:
        rows = []
        for i, (idxs, t) in enumerate(zip(self.allocation.groups,
                                          self.group_times)):
            kinds = [self.allocation.devices[j].kind for j in idxs]
            mix = "+".join(f"{kinds.count(k)}{k}" for k in sorted(set(kinds)))
            rows.append(f"  group {i}: {mix:12s} batch="
                        f"{self.allocation.microbatches[i]:4d} "
                        f"t_conv={t * 1e3:.2f}ms")
        return (f"plan g={self.g} mp={self.mp} "
                f"t_iter={self.t_iteration * 1e3:.2f}ms "
                f"P_SE={self.se_penalty:.2f} "
                f"score={self.time_score * 1e3:.2f}ms\n" + "\n".join(rows))


def group_collective_time(devices: Sequence[DeviceSpec],
                          grad_bytes: float) -> float:
    """Ring reduce-scatter + all-gather within a group, paced by the
    slowest link (same form as ``hardware_model.collective_time``)."""
    k = len(devices)
    if k <= 1 or grad_bytes <= 0.0:
        return 0.0
    bw = min(d.net_bw for d in devices)
    return 2.0 * grad_bytes * (k - 1) / k / bw


def mp_collective_time(devices: Sequence[DeviceSpec], param_bytes: float,
                       mp: int) -> float:
    """Per-step all-gather of the full parameters from a worker's mp
    shards, paced by the slowest link: each device receives the other
    shards, ``param_bytes * (mp-1)/mp`` over the worker's slowest link.
    (Momentum is never gathered — the update is elementwise on the local
    shard; the gradient slice back to the shard is local.)"""
    if mp <= 1 or param_bytes <= 0.0 or not devices:
        return 0.0
    bw = min(d.net_bw for d in devices)
    return param_bytes * (mp - 1) / mp / bw


def mp_feasible(devices: Sequence[DeviceSpec],
                cost: Optional[WorkloadCost], mp: int) -> bool:
    """True iff every device can hold its 1/mp shard of the resident
    parameter/optimizer state. Devices without a ``mem_bytes`` capacity
    (or costs without ``state_bytes``) are unconstrained."""
    if cost is None or cost.state_bytes <= 0.0:
        return True
    need = cost.state_bytes / mp
    return all(d.mem_bytes is None or need <= d.mem_bytes for d in devices)


def group_conv_times(alloc: Allocation,
                     cost: Optional[WorkloadCost] = None,
                     mp: int = 1) -> Tuple[float, ...]:
    """Per-group conv-phase time: compute on the group's microbatch,
    overlapped (max) with its intra-group collective. With ``mp > 1``
    the group's effective throughput divides by mp (storage-only model
    parallelism: every device of a worker computes the full microbatch
    gradient), the gradient exchange carries 1/mp of the bytes, and the
    per-worker parameter gather joins the overlap max."""
    times = []
    grad_bytes = cost.grad_bytes if cost is not None else 0.0
    for i in range(alloc.num_groups):
        comp = alloc.microbatches[i] / (alloc.throughputs[i] / mp)
        devs = alloc.group_devices(i)
        coll = group_collective_time(devs, grad_bytes / mp)
        mpc = mp_collective_time(devs, grad_bytes, mp)
        times.append(max(comp, coll, mpc))
    return tuple(times)


def hetero_time_per_iteration(group_times: Sequence[float],
                              t_fc: float) -> float:
    """HE seconds/iteration for heterogeneous groups + one serial FC server."""
    if not group_times:
        raise ValueError("need at least one group")
    rate = sum(1.0 / (t + t_fc) for t in group_times)
    return max(t_fc, 1.0 / rate)


def plan_for_g_mp(devices: Sequence[DeviceSpec], g: int, mp: int, *,
                  global_batch: int, t_fc: float,
                  cost: Optional[WorkloadCost] = None,
                  mu_star_total: float = 0.9, se_sharpness: float = 4.0,
                  se_penalties: Optional[Mapping[int, float]] = None) -> Plan:
    """Score one (g, mp) candidate: allocate, predict HE, multiply by
    P_SE(g). Raises ``ValueError`` when the point is infeasible — a group
    with fewer than mp devices (a worker needs mp shards), or a device
    that cannot hold its 1/mp of the resident state (``mp_feasible``).

    ``se_penalties`` overrides the analytic SE model with *measured*
    penalties (``stat_model.measured_se_from_replay`` over replayed
    traces) for the g values it contains; others fall back to
    ``predict_se_penalty``.
    """
    if mp < 1:
        raise ValueError(f"mp must be >= 1, got {mp}")
    alloc = allocate(devices, g, global_batch, cost=cost)
    for i in range(alloc.num_groups):
        if len(alloc.group_devices(i)) < mp:
            raise ValueError(
                f"(g={g}, mp={mp}) infeasible: group {i} has "
                f"{len(alloc.group_devices(i))} device(s), a worker "
                f"needs {mp}")
    if not mp_feasible(devices, cost, mp):
        raise ValueError(
            f"(g={g}, mp={mp}) infeasible: state_bytes/{mp} = "
            f"{cost.state_bytes / mp:.3g} exceeds a device's mem_bytes")
    times = group_conv_times(alloc, cost, mp)
    t_iter = hetero_time_per_iteration(times, t_fc)
    if se_penalties is not None and g in se_penalties:
        pse = float(se_penalties[g])
    else:
        pse = predict_se_penalty(g, mu_star_total, sharpness=se_sharpness)
    return Plan(g=g, allocation=alloc, group_times=times, t_iteration=t_iter,
                se_penalty=pse, time_score=t_iter * pse, mp=mp)


def plan_for_g(devices: Sequence[DeviceSpec], g: int, *, global_batch: int,
               t_fc: float, cost: Optional[WorkloadCost] = None,
               mu_star_total: float = 0.9, se_sharpness: float = 4.0,
               se_penalties: Optional[Mapping[int, float]] = None) -> Plan:
    """Score one candidate g at mp=1 (``plan_for_g_mp``)."""
    return plan_for_g_mp(devices, g, 1, global_batch=global_batch, t_fc=t_fc,
                         cost=cost, mu_star_total=mu_star_total,
                         se_sharpness=se_sharpness,
                         se_penalties=se_penalties)


def best_allocation(devices: Sequence[DeviceSpec], *, global_batch: int,
                    t_fc: float, cost: Optional[WorkloadCost] = None,
                    mu_star_total: float = 0.9, se_sharpness: float = 4.0,
                    g_candidates: Optional[Sequence[int]] = None,
                    mp_candidates: Optional[Sequence[int]] = None,
                    se_penalties: Optional[Mapping[int, float]] = None
                    ) -> Plan:
    """Search (g, mp, alloc) for the minimum predicted time-to-convergence.

    Default g candidates: every feasible g (1..min(N, global_batch) —
    each group needs a device and at least one example). Default mp
    candidates: (1,) — pure data parallelism, the pre-mp behavior.
    Infeasible (g, mp) points (a group smaller than mp, or a device that
    cannot hold state_bytes/mp — ``plan_for_g_mp``) are skipped; if no
    point is feasible the last infeasibility is re-raised. Returns the
    best ``Plan``; ties break toward smaller g then smaller mp (less
    staleness and less replication for free).

    ``se_penalties`` (measured P_SE per g, from
    ``stat_model.measured_se_from_replay``) replaces the analytic SE
    penalty for the g values it covers — replay-calibrated planning.
    """
    n = len(devices)
    if g_candidates is None:
        g_candidates = range(1, min(n, global_batch) + 1)
    if mp_candidates is None:
        mp_candidates = (1,)
    best: Optional[Plan] = None
    last_err: Optional[ValueError] = None
    for g in g_candidates:
        if not 1 <= g <= min(n, global_batch):
            raise ValueError(f"candidate g={g} infeasible for N={n}, "
                             f"batch={global_batch}")
        for mp in mp_candidates:
            try:
                plan = plan_for_g_mp(devices, g, mp,
                                     global_batch=global_batch, t_fc=t_fc,
                                     cost=cost, mu_star_total=mu_star_total,
                                     se_sharpness=se_sharpness,
                                     se_penalties=se_penalties)
            except ValueError as e:
                last_err = e
                continue
            if best is None or plan.time_score < best.time_score:
                best = plan
    if best is None:
        raise ValueError(
            f"no feasible (g, mp) point over g={list(g_candidates)!r}, "
            f"mp={list(mp_candidates)!r}") from last_err
    return best
