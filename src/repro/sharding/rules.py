"""Logical sharding rules: param / batch / cache pytrees -> PartitionSpecs.

Megatron/FSDP hybrid:
  - tensor axis: preferred per-leaf dimension by param name (attention
    heads, FFN hidden, vocab), falling back to the largest divisible dim;
  - fsdp axes: largest remaining divisible dim.
Every rule checks divisibility, so the same code shards whisper-base
(d=512, 8 heads) and llama3-405b (d=16384, 128 heads) on a 16-wide tensor
axis without per-arch tables.

Axis names are resolved FROM THE MESH (``default_axes``), not hardcoded:
the legacy production/dryrun meshes name the tensor axis "model" and fsdp
("pod", "data"); the engine's group mesh (``launch.mesh.make_group_mesh``)
names its model-parallel axis "mp" and keeps "group"/"data" replicated for
params. The same rule code serves both.

For the engine's mp-sharded parameter/optimizer storage,
``engine_param_specs`` adds two idioms on top of the name table (both
after redco's deployer utilities — SNIPPETS.md 1-2):

  - explicit ``(regex-path-window, PartitionSpec)`` rules, first match
    wins (``set_partitions``);
  - auto-derivation for leaves no rule or table entry matches
    (``get_sharding_rules``): the trailing-most body dim divisible by the
    mp axis size, 1-D leaves replicated.
"""
from __future__ import annotations

import re
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# preferred tensor-sharded dim (by trailing param name), tried in order
TENSOR_PREF: Dict[str, Tuple[int, ...]] = {
    "wq": (1,), "wk": (1,), "wv": (1,), "wo": (0,),
    "bq": (0,), "bk": (0,), "bv": (0,),
    "w_gate": (-1, 0), "w_up": (-1, 0), "w_down": (-2, -1),
    "tok": (0, 1), "unembed": (1, 0),
    "router": (1,),
    "in_proj": (1,), "out_proj": (0,),
    "w_gate_branch": (1,), "w_rec_in": (1,), "w_a": (1,), "w_x": (1,),
    "w_out": (0,),
    "w": (3, 0),     # CNN conv kernels (HWIO): shard Cout
    "b": (0,),
}


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def default_axes(mesh: Mesh) -> Tuple[Optional[str], Tuple[str, ...]]:
    """(tensor_axis, fsdp_axes) resolved from the mesh's axis names.

    The tensor (model-parallel) axis is "model" when present (legacy
    production/dryrun meshes), else "mp" (the engine's group mesh), else
    None (pure data parallelism). FSDP axes are whichever of ("pod",
    "data") the mesh carries. A mesh's "group" axis is never used by
    param rules — the grouped update requires params replicated across
    groups."""
    if "model" in mesh.shape:
        tensor = "model"
    elif "mp" in mesh.shape:
        tensor = "mp"
    else:
        tensor = None
    return tensor, tuple(a for a in ("pod", "data") if a in mesh.shape)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        key = getattr(entry, "key", None)
        if isinstance(key, str):
            return key
    return ""


def param_spec(path, shape: Tuple[int, ...], mesh: Mesh, *,
               tensor_axis: Optional[str] = "model",
               fsdp_axes: Tuple[str, ...] = ("data",),
               num_stack_dims: int = 0,
               decode_kv_hd: bool = False) -> P:
    """Spec for one param leaf. ``num_stack_dims`` marks leading lax.scan
    stacking dims (layers / super-blocks) that must stay unsharded.
    ``tensor_axis=None`` disables tensor sharding (fsdp only)."""
    name = _leaf_name(path)
    ndim = len(shape)
    assign: Dict[int, object] = {}
    tsize = _axis_size(mesh, tensor_axis)
    body = list(range(num_stack_dims, ndim))

    # 1-D body params (norm scales, biases, per-head scalars) are tiny:
    # replicate. Sharding a norm scale over the tensor axis drags the whole
    # residual stream into d-sharding (measured 15 TB/step of all-reduce).
    if len(body) <= 1 and name not in ("tok",):
        return P(*[None] * ndim)

    def norm(d):
        # TENSOR_PREF indices are relative to the UNSTACKED param layout;
        # shift by the leading lax.scan stacking dims.
        return (d + num_stack_dims) if d >= 0 else ndim + d

    # tensor axis. Attention (and recurrence) weights are STRICT: shard the
    # preferred (head/channel) dim or replicate — a greedy fallback onto the
    # contraction dim turns every attention dot into a partial-sum
    # all-reduce inside the KV-chunk loop (measured 788 GiB/step on
    # qwen2-7b whose 28 heads don't divide the 16-wide axis; §Perf).
    strict = name in ("wq", "wk", "wv", "wo", "bq", "bk", "bv",
                      "w_a", "w_x", "conv_w", "conv_b")
    tdim = None
    prefs = [norm(d) for d in TENSOR_PREF.get(name, ())]
    if decode_kv_hd and name in ("wq", "wk", "wv"):
        # decode-only (§Perf): hd-dim sharding of the projections; the
        # resulting score psums are tiny at Sq=1, while the alternative is
        # re-gathering the weights every layer (23.6 GiB/step, llama3-405b)
        prefs.append(ndim - 1)
    if not strict:
        prefs += sorted(body, key=lambda d: -shape[d])
    if tsize > 1:
        for d in prefs:
            if d in body and shape[d] % tsize == 0 and shape[d] >= tsize:
                tdim = d
                break
    if tdim is not None:
        assign[tdim] = tensor_axis

    # Embedding / unembedding: vocab on tensor axis ONLY. FSDP on d_model
    # would shard the contraction dim of the logits matmul, which makes
    # GSPMD all-reduce the (B,S,V) logits instead of gathering the weight —
    # measured 5.7 GB/step per microbatch on qwen2-7b (EXPERIMENTS.md §Perf).
    if name in ("tok", "unembed"):
        return P(*[assign.get(d) for d in range(ndim)])

    # fsdp axes on the largest remaining divisible dim
    fsize = _axis_size(mesh, fsdp_axes)
    if fsize > 1:
        for d in sorted(body, key=lambda d: -shape[d]):
            if d != tdim and shape[d] % fsize == 0 and shape[d] >= fsize:
                assign[d] = fsdp_axes if len(fsdp_axes) > 1 else fsdp_axes[0]
                break
    return P(*[assign.get(d) for d in range(ndim)])


def _stack_dims(path, cfg) -> int:
    """Leading scan-stacking dims for a param leaf given its tree path."""
    keys = [getattr(e, "key", None) for e in path]
    if "blocks" in keys or "enc" in keys or "rem" in keys:
        return 1
    if "super" in keys:
        # hybrid "rec" and vlm "self" carry (n_super, per) stacking
        return 2 if ("rec" in keys or "self" in keys) else 1
    return 0


def params_shardings(params_shapes, cfg, mesh: Mesh, *,
                     tensor_axis: Optional[str] = None,
                     fsdp_axes: Optional[Tuple[str, ...]] = None,
                     decode_kv_hd: bool = False):
    """NamedShardings for a params (or momentum) pytree of
    ShapeDtypeStructs. Axis names default to ``default_axes(mesh)``."""
    mesh_tensor, mesh_fsdp = default_axes(mesh)
    if tensor_axis is None:
        tensor_axis = mesh_tensor
    if fsdp_axes is None:
        fsdp_axes = mesh_fsdp

    def one(path, leaf):
        spec = param_spec(path, leaf.shape, mesh, tensor_axis=tensor_axis,
                          fsdp_axes=fsdp_axes,
                          num_stack_dims=_stack_dims(path, cfg),
                          decode_kv_hd=decode_kv_hd)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(one, params_shapes)


# ---------------------------------------------------------------------------
# Engine param/optimizer-state specs (mp axis only)
# ---------------------------------------------------------------------------

def _path_keys(path) -> Tuple[str, ...]:
    """Tree path -> string keys (dict keys and sequence indices alike),
    the match target of explicit rules."""
    return tuple(str(getattr(e, "key", getattr(e, "idx", e))) for e in path)


def _match_rule(patterns: Sequence[str], keys: Sequence[str]) -> bool:
    """True if ``patterns`` (regexes, full-match each) match any
    contiguous window of ``keys`` — redco/t5x ``set_partitions``."""
    pats = tuple(re.compile(p + r"$") for p in patterns)
    for i in range(len(keys) - len(pats) + 1):
        window = keys[i:i + len(pats)]
        if all(p.match(k) for p, k in zip(pats, window)):
            return True
    return False


def auto_spec(shape: Tuple[int, ...], size: int, *,
              axis: str, num_stack_dims: int = 0) -> P:
    """Auto-derived spec for a leaf no rule or table entry matches (redco
    ``get_sharding_rules``): shard the trailing-most body dim divisible by
    ``size``; 1-D bodies (and leaves with no divisible dim) replicate."""
    ndim = len(shape)
    body = list(range(num_stack_dims, ndim))
    if size <= 1 or len(body) <= 1:
        return P(*[None] * ndim)
    for d in reversed(body):
        if shape[d] % size == 0 and shape[d] >= size:
            return P(*[axis if i == d else None for i in range(ndim)])
    return P(*[None] * ndim)


def engine_param_specs(params, mesh: Mesh, *, rules=None, mp_axis=None,
                       cfg=None):
    """PartitionSpec tree for the engine's model-parallel param/optimizer
    storage. Only the mesh's model-parallel axis is ever used — "group"
    and "data" stay replicated because the grouped update must run
    identically on every worker of every group.

    Per leaf, first match wins:
      1. an explicit ``(path-regex-window, PartitionSpec)`` entry from
         ``rules`` (the redco ``set_partitions`` idiom);
      2. the ``TENSOR_PREF`` name table via ``param_spec`` (attention /
         FFN / vocab preferences, strictness rules included);
      3. ``auto_spec`` derivation (the redco ``get_sharding_rules``
         idiom) for everything else.

    ``params`` may hold arrays or ShapeDtypeStructs. Every emitted spec
    divides its leaf shape (``param_spec``/``auto_spec`` check
    divisibility; explicit rules are validated here)."""
    if mp_axis is None:
        mp_axis = default_axes(mesh)[0]
    size = int(mesh.shape[mp_axis]) if mp_axis is not None else 1
    rules = tuple(rules or ())

    def one(path, leaf):
        shape = tuple(leaf.shape)
        if size > 1:
            keys = _path_keys(path)
            for patterns, spec in rules:
                if _match_rule(patterns, keys):
                    spec = P(*spec) if not isinstance(spec, P) else spec
                    for d, ax in enumerate(spec):
                        if ax is None:
                            continue
                        s = _axis_size(mesh, ax)
                        if d >= len(shape) or shape[d] % s:
                            raise ValueError(
                                f"rule {patterns} gives spec {spec} which "
                                f"does not divide leaf {keys} of shape "
                                f"{shape}")
                    return spec
        nsd = _stack_dims(path, cfg)
        if _leaf_name(path) in TENSOR_PREF:
            return param_spec(path, shape, mesh, tensor_axis=mp_axis,
                              fsdp_axes=(), num_stack_dims=nsd)
        return auto_spec(shape, size, axis=mp_axis, num_stack_dims=nsd)

    return jax.tree_util.tree_map_with_path(one, params)


def spec_mp_dim(spec: P, axis: str) -> Optional[int]:
    """Dim index ``axis`` shards in ``spec`` (None when replicated)."""
    for d, ax in enumerate(spec):
        if ax == axis or (isinstance(ax, tuple) and axis in ax):
            return d
    return None


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def batch_shardings(batch_shapes, mesh: Mesh, *,
                    tensor_axis: Optional[str] = None, batch_dim: int = 0):
    """Inputs: the batch dim (0, or 1 under grad-accum microbatching) over
    (pod, data) when divisible; the trailing embedding dim of float
    modality-stub inputs over tensor when divisible. Never shard the token
    sequence dim."""
    if tensor_axis is None:
        tensor_axis = default_axes(mesh)[0]
    baxes = batch_axes(mesh)
    bsize = _axis_size(mesh, baxes)
    tsize = _axis_size(mesh, tensor_axis)

    def one(leaf):
        shape = leaf.shape
        assign = {}
        if (len(shape) > batch_dim and bsize > 1
                and shape[batch_dim] % bsize == 0
                and shape[batch_dim] >= bsize):
            assign[batch_dim] = baxes if len(baxes) > 1 else baxes[0]
        is_float = leaf.dtype.kind == "f"
        if (is_float and len(shape) >= batch_dim + 3
                and shape[-1] % tsize == 0
                and shape[-1] >= tsize and tsize > 1):
            assign[len(shape) - 1] = tensor_axis
        return NamedSharding(mesh, P(*[assign.get(d) for d in range(len(shape))]))

    return jax.tree.map(one, batch_shapes)


# cache leaf name -> (batch_dim_from_end_strategy) handled generically below
_CACHE_SEQ_NAMES = {"k", "v", "ck", "cv"}


def cache_shardings(cache_shapes, cfg, mesh: Mesh, *, batch: int,
                    tensor_axis: Optional[str] = None):
    """Decode-cache pytree: batch dim over data axes; for attention k/v the
    ring/window dim over tensor when divisible; for SSM state the head dim
    over tensor."""
    if tensor_axis is None:
        tensor_axis = default_axes(mesh)[0]
    baxes = batch_axes(mesh)
    bsize = _axis_size(mesh, baxes)
    tsize = _axis_size(mesh, tensor_axis)
    baxes_val = baxes if len(baxes) > 1 else (baxes[0] if baxes else None)

    def one(path, leaf):
        name = _leaf_name(path)
        shape = leaf.shape
        assign = {}
        # find the batch dim: first dim equal to `batch` after stack dims
        bdim = None
        for d, s in enumerate(shape):
            if s == batch:
                bdim = d
                break
        if (bdim is not None and bsize > 1 and batch % bsize == 0
                and batch >= bsize):
            assign[bdim] = baxes_val
        if name in _CACHE_SEQ_NAMES and bdim is not None and tsize > 1:
            # (..., B, W, K, hd): try window dim, then kv-head dim
            for d in (bdim + 1, bdim + 2):
                if d < len(shape) and d not in assign \
                        and shape[d] % tsize == 0 and shape[d] >= tsize:
                    assign[d] = tensor_axis
                    break
        elif name == "h" and bdim is not None and tsize > 1:
            d = bdim + 1          # SSM/RG-LRU state: heads / channel dim
            if d < len(shape) and shape[d] % tsize == 0 and shape[d] >= tsize:
                assign[d] = tensor_axis
        elif name == "conv" and bdim is not None and tsize > 1:
            d = len(shape) - 1
            if shape[d] % tsize == 0 and shape[d] >= tsize:
                assign[d] = tensor_axis
        return NamedSharding(mesh, P(*[assign.get(d) for d in range(len(shape))]))

    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# Activation sharding constraints
# ---------------------------------------------------------------------------
# GSPMD sometimes drops the batch sharding inside nested scan bodies (e.g.
# the chunked-attention KV loop: measured 12.5 TB/step of scores all-reduce
# on qwen2-7b once the propagated batch sharding got lost). Model code calls
# ``constrain_batch`` at block boundaries; it is a no-op unless a launcher
# installs a mesh via ``activation_sharding``.

import contextlib
import contextvars

_ACT_CTX = contextvars.ContextVar("repro_act_sharding", default=None)


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, batch_axes_=None, *,
                        seq_parallel_attention: bool = False,
                        tensor_axis: Optional[str] = None,
                        weight_stationary: bool = False):
    if tensor_axis is None:
        tensor_axis = default_axes(mesh)[0]
    axes = batch_axes_ if batch_axes_ is not None else batch_axes(mesh)
    token = _ACT_CTX.set((mesh, axes, seq_parallel_attention, tensor_axis,
                          weight_stationary))
    try:
        yield
    finally:
        _ACT_CTX.reset(token)


def constrain_batch(x, batch_dim: int = 0):
    """Pin activation ``x`` to be sharded on its batch dim over the data
    axes (replicated elsewhere). No-op outside activation_sharding()."""
    ctx = _ACT_CTX.get()
    if ctx is None or not hasattr(x, "ndim"):
        return x
    mesh, axes = ctx[0], ctx[1]
    size = _axis_size(mesh, axes)
    if size <= 1 or x.ndim <= batch_dim or x.shape[batch_dim] % size:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = tuple(axes) if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def seq_parallel_enabled() -> bool:
    ctx = _ACT_CTX.get()
    return bool(ctx and ctx[2])


def maybe_replicate_for_decode(x):
    """Weight-stationary decode (§Perf hillclimb): decode activations are
    tiny (B x 1 x d), so replicate them over the data axes and let the
    FSDP-sharded weights stay put — partial outputs are all-reduced (MBs)
    instead of gathering the weights (51 GiB/step on llama3-405b)."""
    ctx = _ACT_CTX.get()
    if not ctx or len(ctx) < 5 or not ctx[4] or not hasattr(x, "ndim"):
        return x
    mesh = ctx[0]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*[None] * x.ndim)))


def constrain_kv_seq(x, seq_dim: int = 1, batch_dim: int = 0):
    """Sequence-parallel attention (§Perf hillclimb): shard K/V on the
    sequence dim over the tensor axis; each chip scores all queries against
    its KV slice (flash semantics distribute the softmax). Used when the
    head count doesn't divide the tensor axis. Batch stays on data."""
    ctx = _ACT_CTX.get()
    if ctx is None or not hasattr(x, "ndim"):
        return x
    mesh, axes, taxis = ctx[0], ctx[1], ctx[3]
    tsize = _axis_size(mesh, taxis)
    if tsize <= 1 or x.ndim <= seq_dim or x.shape[seq_dim] % tsize:
        return x
    spec = [None] * x.ndim
    spec[seq_dim] = taxis
    bsize = _axis_size(mesh, axes)
    if bsize > 1 and x.shape[batch_dim] % bsize == 0:
        spec[batch_dim] = tuple(axes) if len(axes) > 1 else axes[0]
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
