from repro.sharding import rules
