"""Trace-driven asynchronous SGD replay — execute real model updates along
an ``EventTrace``.

Generalizes ``core.async_sgd.delayed_sgd_run`` from one fixed staleness S
to *per-commit* staleness: commit t applies a momentum-SGD update (paper
eq. (3)-(4)) whose gradient was evaluated at parameter version
``trace.read_version[t]``, kept in a ring buffer of the last R parameter
versions. This is the execution half of the prediction->execution loop:
the simulators predict a staleness distribution, the replay engine runs
SGD along the very event schedule that produced it, and the measured
implicit momentum / statistical efficiency can be compared against
Theorem 1 and the analytic SE penalty.

Three interchangeable implementations:

- ``replay_trace_python`` — plain-Python reference (the semantic oracle);
- ``replay_trace_scan``   — jittable ``lax.scan`` over the trace arrays,
  with staleness bucketed to the ring depth (``depth=``) so arbitrarily
  long tails don't blow up the parameter history;
- ``replay_trace_fused``  — for run-structured traces (every run of L
  commits reads the run-start version, e.g. the grouped strategy), one
  fused pass per run using the ``optim.closed_form`` coefficients instead
  of L sequential sub-steps.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.exec.trace import EventTrace
from repro.optim.closed_form import grouped_coeffs


def _momentum_update(p, g, v, *, lr, momentum, weight_decay):
    """One paper-eq-(3)/(4) leaf update in fp32 (matches ``sgd_update``)."""
    g32 = g.astype(jnp.float32)
    if weight_decay:
        g32 = g32 + weight_decay * p.astype(jnp.float32)
    v_new = momentum * v.astype(jnp.float32) - lr * g32
    p_new = p.astype(jnp.float32) + v_new
    return p_new.astype(p.dtype), v_new.astype(v.dtype)


def _read_slots(trace: EventTrace, depth: Optional[int]) -> tuple:
    """(ring depth R, per-commit ring slot of the read version).

    ``depth`` caps the ring: staleness is bucketed to at most R-1, i.e.
    commits that read a version older than the ring holds read the oldest
    version still alive — ``read_version[t] -> max(rv[t], t - (R-1))``.
    """
    R = trace.max_staleness + 1
    if depth is not None:
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        R = min(R, int(depth))
    t = np.arange(len(trace))
    rv = np.maximum(trace.read_version, t - (R - 1))
    return R, (rv % R).astype(np.int32)


def _slice_batches(batches, T: int):
    lead = jax.tree.leaves(batches)[0].shape[0]
    if lead < T:
        raise ValueError(f"trace has {T} commits but batches only {lead}")
    return jax.tree.map(lambda x: x[:T], batches)


# ---------------------------------------------------------------------------
# Python reference
# ---------------------------------------------------------------------------

def replay_trace_python(loss_fn: Callable, params, batches,
                        trace: EventTrace, *, lr: float,
                        momentum: float = 0.0, weight_decay: float = 0.0,
                        depth: Optional[int] = None,
                        record_params: bool = False):
    """Semantic oracle: per-commit loop over the trace in Python.

    Commit t evaluates ``grad(W_{read_version[t]}, batches[t])`` and
    applies one momentum-SGD update to the current parameters. Losses are
    reported at the stale evaluation point (as in ``delayed_sgd_run``).

    Returns ``(final_params, losses (T,), params_trace or None)``.
    """
    T = len(trace)
    batches = _slice_batches(batches, T)
    R, slots = _read_slots(trace, depth)
    vg = jax.jit(jax.value_and_grad(loss_fn))
    ring = [params] * R                     # ring[v % R] = params at version v
    mom = jax.tree.map(jnp.zeros_like, params)
    losses, ptrace = [], []
    for t in range(T):
        batch = jax.tree.map(lambda x: x[t], batches)
        stale = ring[int(slots[t])]
        cur = ring[t % R]
        loss, grads = vg(stale, batch)
        new = jax.tree.map(
            lambda p, g, v: _momentum_update(
                p, g, v, lr=lr, momentum=momentum,
                weight_decay=weight_decay), cur, grads, mom)
        cur = jax.tree.map(lambda x: x[0], new,
                           is_leaf=lambda x: isinstance(x, tuple))
        mom = jax.tree.map(lambda x: x[1], new,
                           is_leaf=lambda x: isinstance(x, tuple))
        ring[(t + 1) % R] = cur
        losses.append(float(loss))
        if record_params:
            ptrace.append(cur)
    final = ring[T % R]
    trace_out = None
    if record_params:
        trace_out = jax.tree.map(lambda *xs: jnp.stack(xs), *ptrace)
    return final, np.asarray(losses), trace_out


# ---------------------------------------------------------------------------
# Jittable scan
# ---------------------------------------------------------------------------

def _replay_core(loss_fn, params, batches, read_slot, R: int, *, lr,
                 momentum, weight_decay, record_params):
    """Pure-JAX scan body shared by ``replay_trace_scan`` and the vmapped
    momentum experiment. ``read_slot``: (T,) int32 ring slots."""
    flat, tree = jax.tree.flatten(params)
    hist = [jnp.stack([f] * R) for f in flat]
    mom = [jnp.zeros_like(f) for f in flat]

    def step(carry, inp):
        hist, mom, t = carry
        rslot, batch = inp
        stale = tree.unflatten([h[rslot] for h in hist])
        cur = [h[t % R] for h in hist]
        loss, grads = jax.value_and_grad(loss_fn)(stale, batch)
        gflat = tree.flatten_up_to(grads)
        new_flat, new_mom = [], []
        for c, g, v in zip(cur, gflat, mom):
            p_new, v_new = _momentum_update(
                c, g, v, lr=lr, momentum=momentum, weight_decay=weight_decay)
            new_flat.append(p_new)
            new_mom.append(v_new)
        new_hist = [h.at[(t + 1) % R].set(nf)
                    for h, nf in zip(hist, new_flat)]
        out = (tree.unflatten(new_flat) if record_params else None, loss)
        return (new_hist, new_mom, t + 1), out

    (hist, mom, t), (ptrace, losses) = jax.lax.scan(
        step, (hist, mom, jnp.int32(0)), (read_slot, batches))
    final = tree.unflatten([h[t % R] for h in hist])
    return final, losses, ptrace


def replay_trace_scan(loss_fn: Callable, params, batches,
                      trace: EventTrace, *, lr: float, momentum: float = 0.0,
                      weight_decay: float = 0.0,
                      depth: Optional[int] = None,
                      record_params: bool = False):
    """Jittable replay: one ``lax.scan`` over the trace arrays with an
    R-deep ring-buffered parameter history (R = max staleness + 1, capped
    by ``depth`` — staleness beyond the ring is bucketed to R-1).

    Returns ``(final_params, losses (T,), params_trace or None)``.
    """
    T = len(trace)
    batches = _slice_batches(batches, T)
    R, slots = _read_slots(trace, depth)
    final, losses, ptrace = _replay_core(
        loss_fn, params, batches, jnp.asarray(slots), R, lr=lr,
        momentum=momentum, weight_decay=weight_decay,
        record_params=record_params)
    return final, losses, ptrace


# ---------------------------------------------------------------------------
# Closed-form fused replay (run-structured traces)
# ---------------------------------------------------------------------------

def replay_trace_fused(loss_fn: Callable, params, batches,
                       trace: EventTrace, *, lr: float,
                       momentum: float = 0.0, weight_decay: float = 0.0):
    """Replay a run-structured trace (``trace.equal_read_runs() == L``)
    with ONE fused update per run: all L gradients of a run are evaluated
    at the run-start version, so the L sequential momentum sub-steps
    collapse to the ``optim.closed_form`` coefficients — no parameter
    history needed at all.

    Raises ``ValueError`` for traces without equal-read-run structure
    (use ``replay_trace_scan`` there).

    Returns ``(final_params, losses (T,), None)``.
    """
    L = trace.equal_read_runs()
    if L is None:
        raise ValueError(
            "fused replay needs an equal-read-run trace (every run of L "
            "commits reading the run-start version); got per-commit reads "
            "— use replay_trace_scan")
    T = len(trace)
    batches = _slice_batches(batches, T)
    runs = T // L
    batches_r = jax.tree.map(
        lambda x: x.reshape((runs, L) + x.shape[1:]), batches)
    coeffs = grouped_coeffs(L, lr=lr, momentum=momentum,
                            weight_decay=weight_decay)
    a = jnp.asarray(coeffs.a, jnp.float32)
    b = jnp.asarray(coeffs.b, jnp.float32)

    def round_step(carry, batch):
        p, v = carry
        losses, grads = jax.vmap(
            lambda bb: jax.value_and_grad(loss_fn)(p, bb))(batch)

        def upd(pp, gg, vv):
            g32 = gg.astype(jnp.float32)            # (L, ...)
            ext = (slice(None),) + (None,) * (g32.ndim - 1)
            p32 = pp.astype(jnp.float32)
            v32 = vv.astype(jnp.float32)
            p_new = (coeffs.cww * p32 + coeffs.cwv * v32
                     + (a[ext] * g32).sum(axis=0))
            v_new = (coeffs.cvw * p32 + coeffs.cvv * v32
                     + (b[ext] * g32).sum(axis=0))
            return p_new.astype(pp.dtype), v_new.astype(vv.dtype)

        new = jax.tree.map(upd, p, grads, v)
        p = jax.tree.map(lambda x: x[0], new,
                         is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda x: x[1], new,
                         is_leaf=lambda x: isinstance(x, tuple))
        return (p, v), losses

    mom = jax.tree.map(jnp.zeros_like, params)
    (final, mom), losses = jax.lax.scan(round_step, (params, mom), batches_r)
    return final, losses.reshape(-1), None


def replay_trace(loss_fn: Callable, params, batches, trace: EventTrace, *,
                 lr: float, momentum: float = 0.0, weight_decay: float = 0.0,
                 impl: str = "scan", depth: Optional[int] = None,
                 record_params: bool = False):
    """Dispatch to one of the replay implementations (``impl``:
    "python" | "scan" | "fused")."""
    if impl == "python":
        return replay_trace_python(loss_fn, params, batches, trace, lr=lr,
                                   momentum=momentum,
                                   weight_decay=weight_decay, depth=depth,
                                   record_params=record_params)
    if impl == "scan":
        return replay_trace_scan(loss_fn, params, batches, trace, lr=lr,
                                 momentum=momentum,
                                 weight_decay=weight_decay, depth=depth,
                                 record_params=record_params)
    if impl == "fused":
        if record_params:
            raise ValueError("fused replay does not record parameter traces")
        if depth is not None:
            raise ValueError("fused replay keeps no parameter history — "
                             "depth bucketing only applies to python/scan")
        return replay_trace_fused(loss_fn, params, batches, trace, lr=lr,
                                  momentum=momentum,
                                  weight_decay=weight_decay)
    raise ValueError(f"unknown replay impl {impl!r}")


# ---------------------------------------------------------------------------
# Fig. 6 measured-momentum experiment (Theorem 1, executed)
# ---------------------------------------------------------------------------

def replayed_momentum_experiment(g: int, *, eta: float = 0.2,
                                 steps: int = 300, runs: int = 400,
                                 t_conv: float = 1.0, t_fc: float = 1e-3,
                                 a: float = 1.0, w0: float = 1.0,
                                 seed: int = 0,
                                 depth: Optional[int] = None) -> np.ndarray:
    """Run-averaged parameter trajectory of SGD (explicit mu = 0) replayed
    along ``runs`` independent exponential-service traces from
    ``queue_sim.simulate`` on the 1-D quadratic ``loss = a w^2 / 2``.

    Feeding the result (with its analytic gradients ``a * w``) to
    ``implicit_momentum.measure_effective_momentum(..., fit_lr=True)``
    reproduces the paper's Fig. 6 measured-momentum panels: the fitted
    modulus approaches Theorem 1's ``1 - 1/g``.

    All traces replay through the shared jittable scan core, vmapped over
    runs with a common ring depth (default ``6 * g``; rare staleness
    beyond it is bucketed to the ring).
    """
    from repro.core import queue_sim  # local: keeps exec importable alone

    R = int(depth) if depth is not None else 6 * g
    t_idx = np.arange(steps)
    slot_rows = []
    for r in range(runs):
        _, tr = queue_sim.simulate(g=g, t_conv=t_conv, t_fc=t_fc,
                                   iters=steps, exponential=True,
                                   seed=seed + r, return_trace=True)
        # all runs share ONE ring depth R (so the scan can be vmapped), so
        # the slots must be computed against exactly R — not the per-trace
        # ring `_read_slots` would pick
        rv = np.maximum(tr.read_version, t_idx - (R - 1))
        slot_rows.append((rv % R).astype(np.int32))
    slot_mat = jnp.asarray(np.stack(slot_rows))          # (runs, steps)

    def loss_fn(p, batch):
        del batch
        return 0.5 * a * jnp.sum(p["w"] ** 2)

    params = {"w": jnp.float32(w0)}
    batches = jnp.zeros((steps, 0), jnp.float32)          # unused payload

    @jax.jit
    def one(slots):
        _, _, ptrace = _replay_core(
            loss_fn, params, batches, slots, R, lr=eta, momentum=0.0,
            weight_decay=0.0, record_params=True)
        return ptrace["w"]

    trajs = np.asarray(jax.vmap(one)(slot_mat))           # (runs, steps)
    full = np.concatenate(
        [np.full((runs, 1), w0, dtype=np.float64), trajs], axis=1)
    return full.mean(axis=0)
