"""Event traces — the contract between the simulators and the execution
engine.

An ``EventTrace`` records, for every model update ("commit") of an
asynchronous run, which group committed it, which model version the
group's gradient was read at, and when. The model version counter equals
the commit index, so ``staleness[t] = t - read_version[t]`` — exactly the
quantity the discrete-event simulators (``core.queue_sim``,
``cluster.sim``) predict distributions for, and exactly what
``repro.exec.replay`` needs to *execute* real SGD along the same schedule
(paper §IV-A/§IV-C; Fig. 6's measured-momentum experiments).

Traces come from three places:

- ``queue_sim.simulate(..., return_trace=True)`` — homogeneous groups,
  stochastic service times (Theorem 1's assumption A2 when exponential);
- ``cluster.sim.simulate_hetero(..., return_trace=True)`` — per-group
  service times (stragglers, heterogeneous allocations);
- ``EventTrace.round_robin`` — deterministic schedules that reduce the
  replay engine to the two existing reference implementations
  (``delayed_sgd_run`` and the grouped scan step), used by the
  conformance tests.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class EventTrace:
    """Commit-ordered record of one asynchronous run.

    ``group[t]``        — id of the group committing update t
    ``read_version[t]`` — model version the gradient of commit t was
                          evaluated at (0 <= read_version[t] <= t)
    ``commit_time[t]``  — simulated wall-clock time of commit t

    The version counter increments by one per commit, so version t is the
    parameter state *after* t commits and ``staleness = t - read_version``.
    """
    num_groups: int
    group: np.ndarray          # (T,) int32
    read_version: np.ndarray   # (T,) int64
    commit_time: np.ndarray    # (T,) float64

    def __post_init__(self):
        object.__setattr__(self, "group",
                           np.asarray(self.group, dtype=np.int32))
        object.__setattr__(self, "read_version",
                           np.asarray(self.read_version, dtype=np.int64))
        object.__setattr__(self, "commit_time",
                           np.asarray(self.commit_time, dtype=np.float64))
        T = self.group.shape[0]
        if self.read_version.shape != (T,) or self.commit_time.shape != (T,):
            raise ValueError("trace arrays must share one leading dim")
        if self.num_groups < 1:
            raise ValueError("need at least one group")
        t = np.arange(T)
        if ((self.read_version < 0) | (self.read_version > t)).any():
            raise ValueError("read_version must satisfy 0 <= rv[t] <= t")
        if T and ((self.group < 0) | (self.group >= self.num_groups)).any():
            raise ValueError("group ids must lie in [0, num_groups)")

    def __len__(self) -> int:
        return int(self.group.shape[0])

    @property
    def staleness(self) -> np.ndarray:
        """Per-commit staleness  t - read_version[t]  (the paper's S)."""
        return np.arange(len(self), dtype=np.int64) - self.read_version

    @property
    def max_staleness(self) -> int:
        return int(self.staleness.max(initial=0))

    def truncate(self, num_commits: int) -> "EventTrace":
        """First ``num_commits`` commits (valid: read_version[t] <= t)."""
        n = min(int(num_commits), len(self))
        return EventTrace(num_groups=self.num_groups, group=self.group[:n],
                          read_version=self.read_version[:n],
                          commit_time=self.commit_time[:n])

    def equal_read_runs(self) -> Optional[int]:
        """Run length L if the trace is exactly partitioned into runs of L
        consecutive commits that all read the run-start version
        (``read_version[t] == (t // L) * L``) — the structure of the
        grouped execution strategy (Fig. 17(b)), which lets the replay
        engine fuse each run with the ``optim.closed_form`` coefficients.
        Returns None for traces without that structure.
        """
        T = len(self)
        if T == 0:
            return None
        nz = np.nonzero(self.read_version)[0]
        L = int(nz[0]) if nz.size else T
        if L == 0 or T % L:
            return None
        expected = (np.arange(T) // L) * L
        return L if np.array_equal(self.read_version, expected) else None

    # -- deterministic constructors -------------------------------------

    @staticmethod
    def round_robin(num_groups: int, num_commits: int,
                    mode: str = "grouped") -> "EventTrace":
        """Deterministic round-robin schedule, group ``t % g`` commits t.

        ``mode="grouped"``: every commit of round r reads the round-start
        version ``r*g`` (staleness 0..g-1 within the round) — the schedule
        ``make_grouped_train_step`` executes.

        ``mode="delayed"``: commit t reads version ``max(0, t - (g-1))`` —
        constant staleness S = g-1 after the cold history, the schedule
        ``delayed_sgd_run(staleness=g-1)`` executes.
        """
        g, T = int(num_groups), int(num_commits)
        if g < 1:
            raise ValueError("need at least one group")
        t = np.arange(T)
        if mode == "grouped":
            rv = (t // g) * g
        elif mode == "delayed":
            rv = np.maximum(0, t - (g - 1))
        else:
            raise ValueError(f"unknown round-robin mode {mode!r}")
        return EventTrace(num_groups=g, group=(t % g).astype(np.int32),
                          read_version=rv,
                          commit_time=(t + 1).astype(np.float64))

    # -- persistence -----------------------------------------------------

    def save(self, path) -> None:
        """Serialize to ``.npz`` (plain arrays, loadable anywhere)."""
        np.savez(path, num_groups=np.int64(self.num_groups),
                 group=self.group, read_version=self.read_version,
                 commit_time=self.commit_time)

    @staticmethod
    def load(path) -> "EventTrace":
        with np.load(path) as z:
            return EventTrace(num_groups=int(z["num_groups"]),
                              group=z["group"],
                              read_version=z["read_version"],
                              commit_time=z["commit_time"])
