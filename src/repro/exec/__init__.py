"""Trace-driven asynchronous execution engine: ``EventTrace`` records from
the discrete-event simulators, replayed as real SGD updates (Python
reference, jittable scan, or closed-form fused runs)."""
from repro.exec.replay import (replay_trace, replay_trace_fused,
                               replay_trace_python, replay_trace_scan,
                               replayed_momentum_experiment)
from repro.exec.trace import EventTrace

__all__ = ["EventTrace", "replay_trace", "replay_trace_fused",
           "replay_trace_python", "replay_trace_scan",
           "replayed_momentum_experiment"]
