"""CI gate for observability artifacts.

Validates a metrics JSONL sink against the ``obs.metrics`` schema and/or
a Chrome trace export against the trace-event shape (parses, has events,
contains the expected span names). Run by the bench-smoke job right
after the instrumented smoke training run::

    python -m repro.obs.validate --metrics m.jsonl --trace t.json \
        --expect-spans engine.run,engine.step,engine.data_wait

Exit status: 0 = all artifacts valid, 1 = validation failure.
"""
from __future__ import annotations

import json
import sys
from typing import Optional, Sequence


def check_metrics(path, expect_series: Sequence[str] = ()) -> list:
    """Schema-validate the sink; returns failure strings (empty = ok)."""
    from repro.obs.metrics import MetricRegistry, validate_jsonl
    try:
        n = validate_jsonl(path)
    except (ValueError, json.JSONDecodeError, OSError) as e:
        return [f"{path}: {e}"]
    print(f"{path}: {n} records valid (schema ok)")
    reg, _ = MetricRegistry.from_jsonl(path)
    return [f"{path}: expected series {name!r} missing or empty "
            f"(have: {', '.join(reg.names())})"
            for name in expect_series
            if not getattr(reg.get(name), "values", None)]


def check_trace(path, expect_spans: Sequence[str] = ()) -> list:
    """Parse the Chrome trace; returns failure strings (empty = ok)."""
    from repro.obs.chrome_trace import load_span_names
    try:
        with open(path) as fh:
            doc = json.load(fh)
        events = doc["traceEvents"]
    except (KeyError, json.JSONDecodeError, OSError) as e:
        return [f"{path}: not a Chrome trace: {e}"]
    if not isinstance(events, list) or not events:
        return [f"{path}: traceEvents empty"]
    names = load_span_names(path)
    print(f"{path}: {len(events)} events, {len(names)} span names")
    missing = sorted(set(expect_spans) - set(names))
    return [f"{path}: expected span {m!r} absent "
            f"(have: {', '.join(names)})" for m in missing]


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--metrics", default="", help="metrics .jsonl sink")
    ap.add_argument("--trace", default="", help="Chrome trace .json")
    ap.add_argument("--expect-spans", default="",
                    help="comma-separated span names the trace must "
                         "contain")
    ap.add_argument("--expect-series", default="",
                    help="comma-separated series the metrics sink must "
                         "contain non-empty")
    args = ap.parse_args(argv)
    if not args.metrics and not args.trace:
        ap.error("nothing to validate: pass --metrics and/or --trace")
    failures = []
    if args.metrics:
        failures += check_metrics(
            args.metrics,
            [s for s in args.expect_series.split(",") if s])
    if args.trace:
        failures += check_trace(
            args.trace, [s for s in args.expect_spans.split(",") if s])
    for f in failures:
        print(f"FAIL {f}", file=sys.stderr)
    if not failures:
        print("observability artifacts valid")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
