"""Chrome trace-event export — spans, metric series, and EventTraces in
one Perfetto-viewable JSON file.

Produces the legacy Chrome ``traceEvents`` JSON format (open at
https://ui.perfetto.dev or chrome://tracing). Three sources share the
file but not a timeline, so they land on separate *processes*:

- pid 0 ``host``: every finished span of a ``spans.Tracer`` as a
  complete ("X") event — one track (tid) per host thread, nesting
  rendered as the flame graph. Wall-clock microseconds, rebased to the
  earliest span so the trace starts at t=0.
- pid 0, track ``metrics``: every ``Series`` of a ``MetricRegistry`` as
  counter ("C") events at their recorded sample timestamps — step time,
  data wait, loss, ... plotted above the flame graph.
- pid 1 ``exec.trace``: an ``exec.trace.EventTrace`` with one track per
  worker group. Each commit t is a span from the time its
  ``read_version`` became available to its commit time, so staleness is
  the visible *length* of the bar and asynchrony the overlap between
  group tracks. NOTE: these are *simulated* seconds (the trace's own
  clock), deliberately a separate pid from the host wall-clock tracks.

``export_chrome_trace(path, tracer=..., metrics=..., event_trace=...)``
writes the combined file; each source is optional.
"""
from __future__ import annotations

import json
from typing import Optional

PID_HOST = 0
PID_EXEC = 1


def _meta(pid: int, tid: int, name: str, what: str = "thread_name") -> dict:
    return {"ph": "M", "pid": pid, "tid": tid, "name": what,
            "args": {"name": name}}


def span_events(tracer, t_origin: Optional[float] = None) -> list:
    """Tracer records -> complete events (one tid per host thread)."""
    records = tracer.records()
    if not records:
        return []
    if t_origin is None:
        t_origin = min(r.t0 for r in records)
    events = []
    tids = {}
    for r in records:
        tid = tids.setdefault(r.tid, len(tids))
        events.append({
            "name": r.name, "ph": "X", "pid": PID_HOST, "tid": tid,
            "ts": (r.t0 - t_origin) * 1e6,
            "dur": max(0.0, (r.t1 - r.t0) * 1e6),
            "args": {k: _jsonable(v) for k, v in r.attrs.items()},
        })
    events.append(_meta(PID_HOST, 0, "host", "process_name"))
    for ident, tid in tids.items():
        events.append(_meta(PID_HOST, tid,
                            "main" if tid == 0 else f"thread-{tid}"))
    return events


def metric_events(registry, t_origin: Optional[float] = None,
                  tid: int = 9999) -> list:
    """Registry series -> counter events at their sample timestamps.
    Samples recorded without a clock (rehydrated files) are skipped —
    they have no place on the timeline."""
    from repro.obs.metrics import Series
    stamped = []
    for name in registry.names():
        m = registry.get(name)
        if isinstance(m, Series):
            stamped += [(t, name, v) for v, t in zip(m.values, m.times)
                        if t is not None]
    if not stamped:
        return []
    if t_origin is None:
        t_origin = min(t for t, _, _ in stamped)
    events = [{"name": name, "ph": "C", "pid": PID_HOST, "tid": tid,
               "ts": (t - t_origin) * 1e6, "args": {name: v}}
              for t, name, v in sorted(stamped)]
    events.append(_meta(PID_HOST, tid, "metrics"))
    return events


def event_trace_events(trace, name: str = "commit") -> list:
    """EventTrace -> one track per worker group (simulated time, pid 1).

    Commit t renders as a bar from the creation time of the model
    version it read (``commit_time[read_version - 1]``, 0.0 for version
    0) to ``commit_time[t]`` — bar length IS the read-to-commit window,
    so deep staleness is visually long and synchronous execution renders
    as non-overlapping bars.
    """
    events = [_meta(PID_EXEC, 0, "exec.trace (simulated time)",
                    "process_name")]
    ct = trace.commit_time
    for t in range(len(trace)):
        rv = int(trace.read_version[t])
        t_read = float(ct[rv - 1]) if rv > 0 else 0.0
        events.append({
            "name": f"{name} {t}", "ph": "X", "pid": PID_EXEC,
            "tid": int(trace.group[t]),
            "ts": t_read * 1e6,
            "dur": max(0.0, (float(ct[t]) - t_read) * 1e6),
            "args": {"commit": t, "read_version": rv,
                     "staleness": t - rv},
        })
    for gid in range(trace.num_groups):
        events.append(_meta(PID_EXEC, gid, f"group {gid}"))
    return events


def chrome_trace(tracer=None, metrics=None, event_trace=None) -> dict:
    """The combined trace document. Host spans and metric samples share
    one rebased wall-clock origin; the EventTrace keeps its own
    (simulated) clock on its own pid."""
    events = []
    t_origin = None
    if tracer is not None and tracer.records():
        t_origin = min(r.t0 for r in tracer.records())
    if metrics is not None:
        from repro.obs.metrics import Series
        stamps = [t for name in metrics.names()
                  for m in [metrics.get(name)] if isinstance(m, Series)
                  for t in m.times if t is not None]
        if stamps:
            t_origin = min(stamps) if t_origin is None \
                else min(t_origin, min(stamps))
    if tracer is not None:
        events += span_events(tracer, t_origin)
    if metrics is not None:
        events += metric_events(metrics, t_origin)
    if event_trace is not None:
        events += event_trace_events(event_trace)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_chrome_trace(path, tracer=None, metrics=None,
                        event_trace=None) -> int:
    """Write the combined trace JSON; returns the event count."""
    doc = chrome_trace(tracer=tracer, metrics=metrics,
                       event_trace=event_trace)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return len(doc["traceEvents"])


def load_span_names(path) -> tuple:
    """Span/instant names present in an exported trace file (validation
    helper: parses the JSON and keeps only duration events)."""
    with open(path) as fh:
        doc = json.load(fh)
    return tuple(sorted({e["name"] for e in doc["traceEvents"]
                         if e.get("ph") == "X"}))


def _jsonable(v):
    if isinstance(v, (str, int, float, bool, type(None))):
        return v
    return repr(v)
