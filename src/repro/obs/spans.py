"""Nested host-side span tracing — zero-cost when disabled.

A *span* is a named wall-clock interval on the host timeline: the engine
wraps each phase of a training round (data wait, dispatch, block) in one,
the cluster prober and the conv-tile autotuner wrap their probes, and the
Chrome-trace exporter (``obs.chrome_trace``) turns the record stream into
a Perfetto-viewable flame graph. All spans use the repo's one clock,
``engine.timing.monotonic`` (resolved lazily to keep ``repro.obs``
importable on its own).

Two tracer implementations share one interface:

- ``NullTracer`` (the default ``current()`` tracer): ``span()`` returns a
  single shared no-op context manager — no allocation, no clock read, no
  lock. Instrumented hot paths pay ~one attribute lookup + call when
  tracing is off, which is what lets the engine keep its spans compiled
  in unconditionally (the bench gate holds the step time to the
  whole-run baseline).
- ``Tracer``: records ``SpanRecord``s. Nesting depth and parent linkage
  come from a per-thread stack (``threading.local``), so concurrently
  tracing threads (prefetch, probes) never corrupt each other's tree;
  the finished-record list is guarded by a lock.

Usage::

    tracer = Tracer()
    with install(tracer):            # or: Engine(tracer=tracer)
        with span("engine.step", step=i) as sp:
            ...
            sp.set(loss=0.42)        # attrs attached on exit
    tracer.records()                 # -> tuple of SpanRecord
"""
from __future__ import annotations

import dataclasses
import threading
from contextlib import contextmanager
from typing import Callable, Dict, Optional, Tuple


def _default_clock() -> Callable[[], float]:
    # lazy: obs must not import the engine package at module import time
    # (engine.timing imports obs.metrics for the Telemetry facade)
    from repro.engine.timing import monotonic
    return monotonic


@dataclasses.dataclass(frozen=True)
class SpanRecord:
    """One finished span. Times are raw clock seconds (the exporter
    rebases them); ``depth``/``parent`` give the per-thread nesting tree,
    ``tid`` the thread the span ran on."""
    name: str
    t0: float
    t1: float
    depth: int
    tid: int
    index: int                 # commit order within the tracer
    parent: Optional[int]      # index of the enclosing span, if any
    attrs: Dict[str, object]

    @property
    def duration_s(self) -> float:
        return self.t1 - self.t0


class _NullSpan:
    """Shared no-op context manager — the entire cost of a disabled span."""
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class NullTracer:
    """Disabled tracer: every ``span()`` is the same no-op object."""
    enabled = False

    def span(self, name: str, **attrs) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name: str, **attrs) -> None:
        return None

    def records(self) -> Tuple[SpanRecord, ...]:
        return ()


class _Span:
    """Context manager recording one interval on the owning tracer."""
    __slots__ = ("_tracer", "name", "attrs", "_t0", "_depth", "_parent")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs

    def set(self, **attrs) -> "_Span":
        """Attach attrs (e.g. results only known at exit)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_Span":
        tr = self._tracer
        stack = tr._stack()
        self._depth = len(stack)
        self._parent = stack[-1] if stack else None
        stack.append(tr._reserve())
        self._t0 = tr._clock()
        return self

    def __exit__(self, *exc):
        tr = self._tracer
        t1 = tr._clock()
        index = tr._stack().pop()
        tr._commit(SpanRecord(
            name=self.name, t0=self._t0, t1=t1, depth=self._depth,
            tid=threading.get_ident(), index=index, parent=self._parent,
            attrs=self.attrs))
        return False


class Tracer:
    """Recording tracer (module docstring). ``clock`` defaults to
    ``engine.timing.monotonic`` — one clock repo-wide, so span times line
    up with the metric registry's sample timestamps."""
    enabled = True

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self._clock = clock if clock is not None else _default_clock()
        self._lock = threading.Lock()
        self._records: list = []
        self._next = 0
        self._local = threading.local()
        self.t_origin = self._clock()    # export rebase point

    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _reserve(self) -> int:
        with self._lock:
            i = self._next
            self._next += 1
        return i

    def _commit(self, rec: SpanRecord) -> None:
        with self._lock:
            self._records.append(rec)

    def span(self, name: str, **attrs) -> _Span:
        return _Span(self, name, attrs)

    def instant(self, name: str, **attrs) -> None:
        """Zero-duration annotation (e.g. one gradient-exchange bucket's
        layout) at the current time and nesting depth."""
        t = self._clock()
        stack = self._stack()
        self._commit(SpanRecord(
            name=name, t0=t, t1=t, depth=len(stack),
            tid=threading.get_ident(), index=self._reserve(),
            parent=stack[-1] if stack else None, attrs=attrs))

    def records(self) -> Tuple[SpanRecord, ...]:
        """Finished spans in commit (end-time) order."""
        with self._lock:
            return tuple(self._records)

    def span_names(self) -> Tuple[str, ...]:
        return tuple(sorted({r.name for r in self.records()}))


# ---------------------------------------------------------------------------
# current-tracer plumbing: instrumented call sites that cannot thread a
# tracer argument (autotuner probes, cluster probes) go through here.
# ---------------------------------------------------------------------------

_CURRENT = NullTracer()


def current():
    """The installed tracer (a ``NullTracer`` unless one was installed)."""
    return _CURRENT


def install(tracer):
    """Install ``tracer`` as ``current()``. Usable two ways: plainly
    (returns the previous tracer) or as a context manager restoring the
    previous tracer on exit."""
    return _Installed(tracer)


class _Installed:
    """Return value of ``install``: already installed; optionally a CM."""

    def __init__(self, tracer):
        global _CURRENT
        self.previous = _CURRENT
        _CURRENT = tracer

    def __enter__(self):
        return _CURRENT

    def __exit__(self, *exc):
        global _CURRENT
        _CURRENT = self.previous
        return False


def span(name: str, **attrs):
    """``current().span(...)`` — the one-liner for instrumented call
    sites; a shared no-op when tracing is disabled."""
    return _CURRENT.span(name, **attrs)


def instant(name: str, **attrs) -> None:
    _CURRENT.instant(name, **attrs)


@contextmanager
def maybe_traced(enable: bool):
    """Install a fresh ``Tracer`` for the block iff ``enable``; yields the
    tracer (or the null tracer)."""
    if not enable:
        yield _CURRENT
        return
    tracer = Tracer()
    with install(tracer):
        yield tracer
