"""Run-environment metadata — one stamp shared by every emitter.

``run_metadata()`` captures the facts that make two timing measurements
comparable (or not): jax/jaxlib versions, backend, device count and kind,
the effective ``XLA_FLAGS``, and optionally the engine mesh shape. Every
``BENCH_*.json`` emitter stamps it under ``"env"`` and the metrics-JSONL
header carries it as the ``meta.run`` payload, so
``benchmarks/compare.py --normalize`` can *refuse* to normalize across
environments that differ structurally (different device pool, different
jax) instead of silently absorbing the difference into the
machine-speed factor.

``STRICT_KEYS`` is the comparability contract: keys that must match for
a cross-machine normalization to be meaningful. Host speed (CPU model,
core count) deliberately is NOT in it — absorbing *that* is exactly what
``--normalize`` is for.
"""
from __future__ import annotations

import os
import platform
from typing import Optional, Sequence, Tuple

#: env keys that must be equal for --normalize to compare two benches
STRICT_KEYS = ("jax", "backend", "device_kind", "device_count")


def run_metadata(mesh_shape: Optional[Sequence[int]] = None,
                 extra: Optional[dict] = None) -> dict:
    """Flat str->scalar dict (JSONL-header compatible) describing the
    environment this process measures in."""
    import jax
    devs = jax.devices()
    out = {
        "jax": jax.__version__,
        "jaxlib": getattr(__import__("jaxlib"), "__version__", "?"),
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "device_kind": devs[0].device_kind if devs else "none",
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "python": platform.python_version(),
        "machine": platform.machine(),
    }
    if mesh_shape is not None:
        out["mesh_shape"] = "x".join(str(int(d)) for d in mesh_shape)
    if extra:
        out.update(extra)
    return out


def env_mismatches(base: Optional[dict], fresh: Optional[dict],
                   keys: Sequence[str] = STRICT_KEYS
                   ) -> Tuple[str, ...]:
    """Strict-key differences between two ``run_metadata`` stamps, as
    human-readable strings; empty when comparable. Stamps that are absent
    (pre-observability baselines) compare as unknown-but-compatible —
    refusing would brick the gate on every legacy file."""
    if not base or not fresh:
        return ()
    return tuple(f"{k}: base={base[k]!r} fresh={fresh[k]!r}"
                 for k in keys
                 if k in base and k in fresh and base[k] != fresh[k])
