"""Post-run HE x SE decomposition — the planner's prediction, measured.

The planner (``cluster.planner``) predicts time-to-convergence as
``T(g, alloc) = HE * P_SE``: seconds per *commit* (one group's model
update) times the statistical-efficiency penalty. A live run measures
the other side of that equation: the engine's metric stream records
wall seconds per *round* (all g groups commit once per grouped step), so

    HE_measured = median steady step_s / g

``hexse_report`` recomputes ``T`` from a run's own metrics and diffs it
against the plan — closing the predict->measure loop the paper's
optimizer rests on, and the drift signal ROADMAP's online
``rebalance()`` consumes. ``calibrated_plan`` builds the fair-comparison
plan: DeviceSpecs whose throughput comes from the very metrics stream
under test (``cluster.spec_from_telemetry``'s contract, generalized to a
windowed stream), so prediction error isolates the queueing model rather
than roofline guesswork.

Also usable from the shell on a metrics sink file::

    python -m repro.obs.report metrics.jsonl --groups 2 --batch 64
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple


def _steady(series, skip: int = 1):
    vals = series.values if hasattr(series, "values") else list(series)
    return vals[skip:] if len(vals) > skip else list(vals)


def measured_step_stats(metrics, skip: int = 1):
    """min/median/IQR ``TimeStats`` of the steady step_s stream (the same
    estimator the bench emitters use — ``engine.timing.stats_of``)."""
    from repro.engine.timing import stats_of
    series = metrics.series("step_s") if hasattr(metrics, "series") \
        else metrics
    steady = _steady(series, skip)
    if not steady:
        raise ValueError("metrics stream has no steady step_s samples")
    return stats_of(steady)


@dataclasses.dataclass(frozen=True)
class HexSeReport:
    """Measured-vs-predicted decomposition of one run against one plan."""
    g: int
    steps: int                       # steady steps measured
    he_measured_s: float             # measured seconds per commit
    he_predicted_s: float            # plan.t_iteration
    se_penalty: float                # plan's P_SE(g)
    t_measured_s: float              # HE_measured * P_SE
    t_predicted_s: float             # plan.time_score
    data_wait_frac: float            # host-side wait / (wait + step)
    he_rel_err: float                # |measured - predicted| / predicted

    def within(self, tol: float) -> bool:
        return self.he_rel_err <= tol

    def render(self) -> str:
        return (
            f"HE x SE decomposition (g={self.g}, {self.steps} steady "
            f"steps)\n"
            f"  HE   measured {self.he_measured_s * 1e3:9.3f} ms/commit"
            f"   predicted {self.he_predicted_s * 1e3:9.3f} ms/commit"
            f"   err {self.he_rel_err:.1%}\n"
            f"  P_SE {self.se_penalty:9.3f}\n"
            f"  T    measured {self.t_measured_s * 1e3:9.3f} ms"
            f"           predicted {self.t_predicted_s * 1e3:9.3f} ms\n"
            f"  host data wait: {self.data_wait_frac:.1%} of the loop")


def hexse_report(metrics, plan, *, skip: int = 1) -> HexSeReport:
    """Recompute ``T(g, alloc)`` from a run's metric stream (or a
    ``Telemetry`` facade — both expose ``series``/``registry``) and diff
    it against ``plan``'s prediction (module doc)."""
    reg = getattr(metrics, "registry", metrics)
    stats = measured_step_stats(reg, skip=skip)
    he_measured = stats.median_s / plan.g
    waits = _steady(reg.series("data_wait_s"), skip)
    steps = _steady(reg.series("step_s"), skip)
    tot_wait, tot_step = sum(waits), sum(steps)
    wait_frac = tot_wait / (tot_wait + tot_step) if tot_step > 0 else 0.0
    return HexSeReport(
        g=plan.g, steps=stats.iters,
        he_measured_s=he_measured, he_predicted_s=plan.t_iteration,
        se_penalty=plan.se_penalty,
        t_measured_s=he_measured * plan.se_penalty,
        t_predicted_s=plan.time_score,
        data_wait_frac=wait_frac,
        he_rel_err=abs(he_measured - plan.t_iteration)
        / plan.t_iteration)


def calibrated_plan(metrics, *, g: int, global_batch: int,
                    devices_per_group: int = 1, t_fc: float = 1e-6,
                    skip: int = 1, window: Optional[int] = None,
                    kind: str = "cpu"):
    """A ``Plan`` whose device throughputs are calibrated from the run's
    own metrics stream — the richer-stream successor of
    ``cluster.spec_from_telemetry``.

    The engine's g groups execute one *round* per step concurrently, so a
    group's service time is the round wall time and its throughput is
    ``(global_batch / g) / step_s``; each of the group's
    ``devices_per_group`` device slots carries an equal share. ``window``
    keeps only the last N steady steps (time-varying recalibration — the
    OmniLearn drift hook).
    """
    from repro.cluster.devices import DeviceSpec
    from repro.cluster.planner import plan_for_g
    reg = getattr(metrics, "registry", metrics)
    steady = _steady(reg.series("step_s"), skip)
    if window is not None:
        steady = steady[-int(window):]
    if not steady:
        raise ValueError("no steady step_s samples to calibrate from")
    from repro.engine.timing import stats_of
    step_s = stats_of(steady).median_s
    per_device = (global_batch / g) / step_s / devices_per_group
    spec = DeviceSpec("calibrated", kind, peak_flops=1.0, mem_bw=1.0,
                      net_bw=1e12, throughput=per_device)
    return plan_for_g([spec] * (g * devices_per_group), g,
                      global_batch=global_batch, t_fc=t_fc)


def summarize(registry, run: Optional[dict] = None,
              skip: int = 1) -> Tuple[str, ...]:
    """Human-readable lines for a metrics stream without a plan (the CLI
    path: everything the sink file alone supports)."""
    from repro.engine.timing import stats_of
    lines = []
    if run:
        lines.append("run: " + ", ".join(f"{k}={v}" for k, v in
                                         sorted(run.items())))
    for name in registry.names():
        m = registry.get(name)
        if hasattr(m, "values") and m.values:
            s = stats_of(_steady(m, skip))
            lines.append(f"series {name}: n={len(m)} min={s.min_s:.6g} "
                         f"median={s.median_s:.6g} iqr={s.iqr_s:.6g}")
        elif hasattr(m, "value") and m.value is not None:
            lines.append(f"{type(m).__name__.lower()} {name}: {m.value}")
    for msg in registry.notes:
        lines.append(f"note: {msg}")
    return tuple(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse
    from repro.obs.metrics import MetricRegistry
    ap = argparse.ArgumentParser(
        description="summarize a metrics JSONL sink; with --groups and "
                    "--batch, run the HE x SE decomposition against a "
                    "plan calibrated from the stream itself")
    ap.add_argument("metrics", help="metrics .jsonl file")
    ap.add_argument("--skip", type=int, default=1,
                    help="leading (compile) steps to drop (default 1)")
    ap.add_argument("--groups", type=int, default=0)
    ap.add_argument("--batch", type=int, default=0)
    ap.add_argument("--window", type=int, default=0,
                    help="calibrate from only the last N steady steps")
    args = ap.parse_args(argv)
    reg, run = MetricRegistry.from_jsonl(args.metrics)
    for line in summarize(reg, run, skip=args.skip):
        print(line)
    if args.groups and args.batch:
        plan = calibrated_plan(reg, g=args.groups,
                               global_batch=args.batch, skip=args.skip,
                               window=args.window or None)
        print(hexse_report(reg, plan, skip=args.skip).render())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
