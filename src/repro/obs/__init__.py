"""Run-level observability: span tracing, typed metrics, Chrome-trace
export, and the measured HE x SE decomposition (see
docs/observability.md).

- ``obs.spans``        nested thread-safe span tracer, zero-cost when off
- ``obs.metrics``      counters/gauges/series + schema-validated JSONL
- ``obs.chrome_trace`` spans + metrics + EventTraces -> Perfetto
- ``obs.report``       recompute the planner's T(g,alloc) from a run
- ``obs.meta``         run-environment stamp shared by bench emitters
"""
from repro.obs import spans
from repro.obs.chrome_trace import chrome_trace, export_chrome_trace
from repro.obs.meta import env_mismatches, run_metadata
from repro.obs.metrics import (Counter, Gauge, MetricRegistry, Series,
                               validate_jsonl, validate_record)
from repro.obs.spans import NullTracer, Tracer

# repro.obs.report (calibrated_plan / hexse_report) is imported lazily by
# its consumers: importing it here would shadow its ``python -m`` entry
# point (runpy double-import) and pull the cluster subsystem into every
# ``import repro.obs``.
