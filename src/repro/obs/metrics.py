"""Typed per-step metric registry with a schema-validated JSONL sink.

Three metric types, each get-or-create by name (a name is permanently
bound to its first type — re-requesting it as another type is an error,
not a silent shadow):

- ``Counter`` — monotone event count (steps run, checkpoints written);
- ``Gauge``   — last-write-wins scalar (replay max staleness, mesh k);
- ``Series``  — an append-only per-step stream (``step_s``,
  ``data_wait_s``, ``h2d_s``, ``loss``, per-group service times, ...),
  each sample carrying its index and a clock timestamp so the
  Chrome-trace exporter can place it on the run timeline.

``MetricRegistry`` is what ``engine.timing.Telemetry`` is a facade over:
the engine's per-step wall-clock record and the run-level metrics stream
are the same data. The JSONL sink (``to_jsonl`` / ``from_jsonl``) is the
on-disk contract — every line validates against ``validate_record``
(kind-discriminated, versioned via ``SCHEMA_VERSION``), and CI's
observability smoke re-validates emitted files on every run.

Schema (one JSON object per line)::

    {"kind": "meta",    "schema": 1, "run": {<str: scalar>...}}
    {"kind": "counter", "name": str, "value": int}
    {"kind": "gauge",   "name": str, "value": number}
    {"kind": "sample",  "name": str, "index": int, "t": number|null,
     "value": number}
    {"kind": "note",    "msg": str}

The first line must be the ``meta`` header; ``counter``/``gauge`` lines
record final values, ``sample`` lines the full per-step streams in append
order.
"""
from __future__ import annotations

import json
import math
from typing import Callable, Dict, List, Optional, Tuple

SCHEMA_VERSION = 1

_SCALAR = (str, int, float, bool, type(None))


def _default_clock() -> Callable[[], float]:
    from repro.engine.timing import monotonic   # lazy (see obs.spans)
    return monotonic


class Counter:
    """Monotone event counter."""
    __slots__ = ("name", "value")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> int:
        if n < 0:
            raise ValueError(f"counter {self.name!r} cannot decrease")
        self.value += int(n)
        return self.value


class Gauge:
    """Last-write-wins scalar."""
    __slots__ = ("name", "value")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, value: float) -> None:
        self.value = float(value)


class Series:
    """Append-only per-step stream; ``values[i]`` was recorded for step
    ``steps[i]`` at clock time ``times[i]`` (None when recorded without a
    clock, e.g. rehydrated from JSONL)."""
    __slots__ = ("name", "values", "steps", "times", "_clock")
    kind = "series"

    def __init__(self, name: str, clock: Optional[Callable] = None):
        self.name = name
        self.values: List[float] = []
        self.steps: List[int] = []
        self.times: List[Optional[float]] = []
        self._clock = clock

    def append(self, value: float, step: Optional[int] = None,
               t: Optional[float] = None) -> None:
        if step is None:
            step = len(self.values)
        if t is None and self._clock is not None:
            t = self._clock()
        self.values.append(float(value))
        self.steps.append(int(step))
        self.times.append(t)

    def __len__(self) -> int:
        return len(self.values)


class MetricRegistry:
    """Get-or-create typed metrics + deduplicated notes (module doc)."""

    def __init__(self, clock: Optional[Callable[[], float]] = None):
        self._clock = clock if clock is not None else _default_clock()
        self._metrics: Dict[str, object] = {}
        self.notes: List[str] = []

    def _get(self, name: str, cls):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, self._clock) if cls is Series else cls(name)
            self._metrics[name] = m
        elif not isinstance(m, cls):
            raise TypeError(f"metric {name!r} is a {type(m).__name__}, "
                            f"requested as {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def series(self, name: str) -> Series:
        return self._get(name, Series)

    def note(self, msg: str) -> None:
        """Deduplicated free-text observation (``Telemetry.note``)."""
        msg = str(msg)
        if msg not in self.notes:
            self.notes.append(msg)

    def names(self) -> Tuple[str, ...]:
        return tuple(sorted(self._metrics))

    def get(self, name: str):
        return self._metrics.get(name)

    # -- JSONL sink ------------------------------------------------------

    def records(self, run: Optional[dict] = None):
        """Yield schema records (module doc) — header first, then final
        counter/gauge values, then every series sample in append order,
        then notes."""
        yield {"kind": "meta", "schema": SCHEMA_VERSION,
               "run": dict(run or {})}
        for name in self.names():
            m = self._metrics[name]
            if isinstance(m, Counter):
                yield {"kind": "counter", "name": name, "value": m.value}
            elif isinstance(m, Gauge) and m.value is not None:
                yield {"kind": "gauge", "name": name, "value": m.value}
        for name in self.names():
            m = self._metrics[name]
            if isinstance(m, Series):
                for v, s, t in zip(m.values, m.steps, m.times):
                    yield {"kind": "sample", "name": name, "index": s,
                           "t": t, "value": v}
        for msg in self.notes:
            yield {"kind": "note", "msg": msg}

    def to_jsonl(self, path, run: Optional[dict] = None) -> int:
        """Write the validated record stream; returns the line count."""
        n = 0
        with open(path, "w") as fh:
            for rec in self.records(run):
                validate_record(rec)
                fh.write(json.dumps(rec) + "\n")
                n += 1
        return n

    @staticmethod
    def from_jsonl(path) -> Tuple["MetricRegistry", dict]:
        """Rehydrate ``(registry, run_meta)`` from a validated sink file
        (sample timestamps are preserved, not re-clocked)."""
        reg = MetricRegistry()
        run: dict = {}
        with open(path) as fh:
            for lineno, line in enumerate(fh, 1):
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                validate_record(rec, where=f"{path}:{lineno}")
                kind = rec["kind"]
                if kind == "meta":
                    run = rec["run"]
                elif kind == "counter":
                    reg.counter(rec["name"]).inc(rec["value"])
                elif kind == "gauge":
                    reg.gauge(rec["name"]).set(rec["value"])
                elif kind == "sample":
                    reg.series(rec["name"]).append(
                        rec["value"], step=rec["index"], t=rec["t"])
                elif kind == "note":
                    reg.note(rec["msg"])
        return reg, run


# ---------------------------------------------------------------------------
# schema validation (dependency-free; jsonschema is not in the image)
# ---------------------------------------------------------------------------

#: kind -> {field: validator}; every listed field is required and no
#: other fields are allowed (strict schema — additions bump the version).
_FIELDS = {
    "meta": {"schema": lambda v: v == SCHEMA_VERSION,
             "run": lambda v: isinstance(v, dict) and all(
                 isinstance(k, str) and isinstance(x, _SCALAR)
                 for k, x in v.items())},
    "counter": {"name": lambda v: isinstance(v, str) and v,
                "value": lambda v: isinstance(v, int)
                and not isinstance(v, bool) and v >= 0},
    "gauge": {"name": lambda v: isinstance(v, str) and v,
              "value": lambda v: _is_num(v)},
    "sample": {"name": lambda v: isinstance(v, str) and v,
               "index": lambda v: isinstance(v, int)
               and not isinstance(v, bool) and v >= 0,
               "t": lambda v: v is None or _is_num(v, finite=True),
               "value": lambda v: _is_num(v)},
    "note": {"msg": lambda v: isinstance(v, str)},
}


def _is_num(v, finite: bool = False) -> bool:
    ok = isinstance(v, (int, float)) and not isinstance(v, bool)
    return ok and (not finite or math.isfinite(v))


def validate_record(rec, where: str = "") -> None:
    """Raise ``ValueError`` unless ``rec`` matches the JSONL schema."""
    ctx = f" ({where})" if where else ""
    if not isinstance(rec, dict):
        raise ValueError(f"record is not an object{ctx}: {rec!r}")
    kind = rec.get("kind")
    fields = _FIELDS.get(kind)
    if fields is None:
        raise ValueError(f"unknown record kind {kind!r}{ctx}")
    extra = set(rec) - set(fields) - {"kind"}
    missing = set(fields) - set(rec)
    if extra or missing:
        raise ValueError(f"{kind} record fields: missing {sorted(missing)}, "
                         f"unexpected {sorted(extra)}{ctx}")
    for field, check in fields.items():
        if not check(rec[field]):
            raise ValueError(
                f"bad {kind}.{field} value {rec[field]!r}{ctx}")


def validate_jsonl(path) -> int:
    """Validate every line of a sink file (header-first enforced);
    returns the record count."""
    n = 0
    with open(path) as fh:
        for lineno, line in enumerate(fh, 1):
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            validate_record(rec, where=f"{path}:{lineno}")
            if n == 0 and rec["kind"] != "meta":
                raise ValueError(f"{path}: first record must be the meta "
                                 f"header, got {rec['kind']!r}")
            n += 1
    if n == 0:
        raise ValueError(f"{path}: empty metrics file")
    return n
