"""Unified mesh-sharded execution engine: one ``Engine`` behind train /
Algorithm 1 / replay, with real SPMD compute groups (see docs/engine.md)."""
from repro.engine.buckets import Bucket, assign_buckets
from repro.engine.engine import Engine
from repro.engine.spmd import (DEFAULT_BUCKET_BYTES, StrandedDevicesWarning,
                               choose_data_parallel, device_batch_split,
                               make_reference_grouped_step,
                               make_spmd_grouped_step)
from repro.engine.strategies import get_strategy, list_strategies
from repro.engine.timing import Telemetry, monotonic
