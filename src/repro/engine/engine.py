"""The unified execution engine — one object behind every training loop.

``Engine`` owns what the four previous per-caller loops each re-implemented
(``launch/train.py``'s hand-rolled loop + ``_replay_main``, the ad-hoc
Runner closures behind Algorithm 1, the replay drivers, the experiment
scripts):

- mesh construction (``launch.mesh.make_group_mesh``) and the
  ("group", "data") SPMD grouped step (``engine.spmd``) when devices are
  available, with a bit-exact single-device reference and the legacy
  vmapped path as fallbacks;
- parameter/batch placement and buffer donation of the jitted step;
- host-side batch preparation (group split, sized heterogeneous shares,
  per-device shards) and prefetch;
- per-step observability: ``engine.timing.Telemetry`` is a facade over an
  ``obs.metrics.MetricRegistry`` (step_s / data_wait_s / h2d_s / loss
  series — the stream the cluster subsystem calibrates from and
  ``train.py --metrics-out`` sinks to JSONL), and every phase of a round
  (data wait, dispatch, block, checkpoint) runs inside an ``obs.spans``
  span — zero-cost no-ops unless a tracer is installed, Chrome-trace
  exportable when one is (docs/observability.md);
- checkpoint hooks;
- the Algorithm-1 ``Runner`` protocol: an Engine *is* a Runner —
  ``engine(state, g=..., mu=..., eta=..., steps=..., probe=...)``.

Execution strategies are plugins (``engine.strategies``): ``sync``,
``grouped-fused``, ``grouped-scan``, ``trace-replay`` (+ ``delayed``, the
Theorem-1-exact CPU substrate).
"""
from __future__ import annotations

from typing import Callable, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compute_groups import GroupSpec
from repro.data.pipeline import prefetch
from repro.engine import timing
from repro.engine.spmd import DEFAULT_BUCKET_BYTES, choose_data_parallel
from repro.engine.strategies import Strategy, get_strategy
from repro.obs import spans

_END = object()     # prefetch-exhausted sentinel (run's data-wait spans)


class Engine:
    """Unified mesh-sharded execution engine (see module docstring).

    ``loss_fn(params, batch) -> scalar`` is the only model contract — the
    engine is model-agnostic (transformer, CNN, MLP, LSTM share one loop).

    Execution placement (``exec_mode``):
      "auto"       SPMD mesh when >= g devices are visible, else the
                   legacy single-device vmapped step
      "spmd"       require the ("group", "data") mesh (error if the
                   device pool is too small)
      "reference"  the single-device bit-exact twin of the SPMD step
                   (lax.map over the same (g, k) shard structure)
      "vmap"       the legacy single-device path
                   (``core.async_sgd.make_grouped_train_step``)

    ``sample_batches(key, steps, batch_size)`` + ``batch_size`` enable the
    Runner protocol (Algorithm 1). ``trace`` + strategy "trace-replay"
    switch ``run`` to executing along the recorded event schedule.

    ``bucket_bytes`` sets the slab size target of the SPMD step's
    overlapped bucketed gradient exchange (``engine.spmd``; 0 restores
    the legacy whole-tree gather).

    ``mp`` adds a model-parallel axis to the SPMD mesh: each of the g
    groups spends mp devices per worker on parameter/optimizer-state
    shards (``sharding.rules.engine_param_specs``), so the device budget
    becomes g*k*mp. ``sharding_rules`` optionally overrides the derived
    PartitionSpecs with explicit ``(regex-path-window, spec)`` rules
    (first match wins). Results stay bitwise equal to ``mp=1`` and to
    the reference path (``engine.spmd`` module doc).

    ``tracer``: an ``obs.spans`` tracer recording the engine's phase
    spans (run / data_wait / dispatch / block_until_ready / checkpoint,
    plus per-bucket exchange annotations on the SPMD path). Defaults to
    the tracer installed via ``obs.spans.install()`` at construction
    time — a shared no-op when none is.
    """

    def __init__(self, loss_fn: Callable, *, strategy: str = "grouped-fused",
                 num_groups: int = 1, lr: float = 0.02, momentum: float = 0.0,
                 weight_decay: float = 0.0,
                 group_weights: Optional[Sequence[float]] = None,
                 micro_sizes: Optional[Sequence[int]] = None,
                 head_filter: Optional[Callable] = None,
                 update_impl: str = "xla", interpret: Optional[bool] = None,
                 exec_mode: str = "auto", num_devices: Optional[int] = None,
                 mp: int = 1, sharding_rules=None,
                 donate: bool = True,
                 bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                 sample_batches: Optional[Callable] = None,
                 batch_size: Optional[int] = None, seed: int = 0,
                 trace=None, replay_impl: str = "scan",
                 replay_depth: Optional[int] = None,
                 checkpoint_dir: str = "", checkpoint_every: int = 0,
                 prefetch_depth: int = 2, telemetry_skip: int = 1,
                 tracer=None):
        if exec_mode not in ("auto", "spmd", "reference", "vmap"):
            raise ValueError(f"unknown exec_mode {exec_mode!r}")
        self.loss_fn = loss_fn
        self.strategy: Strategy = get_strategy(strategy)
        self.num_groups = int(num_groups)
        if self.strategy.name == "sync" and self.num_groups != 1:
            raise ValueError(f"strategy 'sync' is pinned to g=1, got "
                             f"g={self.num_groups}; use grouped-fused/"
                             "grouped-scan for g>1")
        self.lr, self.momentum, self.weight_decay = lr, momentum, weight_decay
        self.group_weights = (tuple(float(w) for w in group_weights)
                              if group_weights is not None else None)
        self.micro_sizes = (tuple(int(s) for s in micro_sizes)
                            if micro_sizes is not None else None)
        self.head_filter = head_filter
        self.update_impl, self.interpret = update_impl, interpret
        self.exec_mode, self.num_devices = exec_mode, num_devices
        self.mp = int(mp)
        if self.mp < 1:
            raise ValueError(f"mp must be >= 1, got {mp}")
        if self.mp > 1 and exec_mode == "vmap":
            raise ValueError("exec_mode='vmap' has no model-parallel path; "
                             "use exec_mode='spmd' (or 'auto') for mp > 1")
        self.sharding_rules = (tuple(sharding_rules)
                               if sharding_rules is not None else None)
        self.donate = donate
        self.bucket_bytes = int(bucket_bytes)
        self.sample_batches, self.batch_size = sample_batches, batch_size
        self.seed = seed
        self.trace = trace
        self.replay_impl, self.replay_depth = replay_impl, replay_depth
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.prefetch_depth = prefetch_depth
        self.telemetry = timing.Telemetry(skip=telemetry_skip)
        # span tracer: the one installed via obs.spans.install() unless
        # given explicitly; a NullTracer (shared no-op spans) by default
        self.tracer = tracer if tracer is not None else spans.current()
        self._steps: dict = {}

    # ------------------------------------------------------------------
    # configuration resolution
    # ------------------------------------------------------------------

    def _weights_for(self, g: int):
        if self.group_weights is not None and len(self.group_weights) == g:
            return self.group_weights
        return None

    def _sizes_for(self, g: int):
        if self.micro_sizes is not None and len(self.micro_sizes) == g:
            return self.micro_sizes
        return None

    def _per_group_batch(self, g: int, global_batch: int) -> int:
        sizes = self._sizes_for(g)
        if sizes is not None:
            return max(sizes)     # sized splits wrap-fill to max(sizes)
        if global_batch % g:
            raise ValueError(f"batch {global_batch} not divisible by g={g}")
        return global_batch // g

    def _resolve_exec(self, g: int, per_group_batch: int):
        """-> (mode, k, mesh or None) for one g. The device budget is
        g*mp workers wide: each of the g groups spends mp devices per
        worker on model-parallel shards, so k data-parallel slots per
        group come out of n // (g * mp)."""
        n = self.num_devices if self.num_devices is not None \
            else jax.device_count()
        mp = self.mp
        if self.exec_mode == "vmap":
            return "vmap", 1, None
        if self.exec_mode == "reference":
            # runs on ONE device; n (num_devices= or the visible pool) only
            # shapes the (g, k) shard structure being mirrored — stranding
            # is not a real-hardware concern here, so no warning. mp only
            # narrows k the same way it narrows the SPMD mesh (the
            # reference is the bitwise target of the mp-sharded step, so
            # the mirrored (g, k) must match).
            return ("reference",
                    choose_data_parallel(per_group_batch,
                                         max(1, n // (g * mp)), warn=False),
                    None)
        slots = n // (g * mp)
        k = choose_data_parallel(per_group_batch, slots) if slots >= 1 else 0
        if self.exec_mode == "auto" and mp == 1 and (n <= 1 or k < 1):
            return "vmap", 1, None
        if k < 1:
            raise ValueError(
                f"exec_mode={self.exec_mode!r} needs >= {g * mp} devices "
                f"for g={g}, mp={mp} (have {n})")
        if k < slots:
            self.telemetry.note(
                f"stranded devices: g={g} mp={mp} uses k={k} of {slots} "
                f"per-group device slots (per-group batch "
                f"{per_group_batch} has no larger divisor)")
        from repro.launch.mesh import make_group_mesh
        return "spmd", k, make_group_mesh(g, k, mp)

    def _built_step(self, strategy: Strategy, *, g: int, lr: float,
                    momentum: float, per_group_batch: int):
        # donate is deliberately NOT part of the key: the step is compiled
        # once (donating iff self.donate) and non-owning callers protect
        # their buffers via _BuiltStep.protected_call, so run()-then-step()
        # on the same config reuses the compile instead of re-jitting
        key = (strategy.name, g, lr, momentum, per_group_batch)
        step = self._steps.get(key)
        if step is None:
            step = strategy.build_step(self, g=g, lr=lr, momentum=momentum,
                                       per_group_batch=per_group_batch,
                                       donate=self.donate)
            self._steps[key] = step
        return step

    def group_spec(self, g: Optional[int] = None) -> GroupSpec:
        g = self.num_groups if g is None else g
        n = self.num_devices if self.num_devices is not None \
            else jax.device_count()
        return GroupSpec(num_groups=g, num_devices=max(g, (n // g) * g))

    def describe(self, g: Optional[int] = None,
                 per_group_batch: Optional[int] = None) -> str:
        g = self.num_groups if g is None else g
        spec = self.group_spec(g)
        mode, k, _ = self._resolve_exec(
            g, per_group_batch if per_group_batch is not None
            else max(1, spec.group_size))
        mesh_s = ""
        if mode == "spmd":
            mesh_s = (f"({g}x{k}x{self.mp} mesh)" if self.mp > 1
                      else f"({g}x{k} mesh)")
        return (f"engine[{self.strategy.name}] g={g} S={spec.staleness} "
                f"mu_implicit={spec.implicit_momentum:.3f} "
                f"exec={mode}" + mesh_s)

    # ------------------------------------------------------------------
    # per-round step
    # ------------------------------------------------------------------

    def step(self, params, mom, batch):
        """One timed round on the global ``batch`` (leaves (B, ...)).
        Returns ``(params, mom, loss)``; wall time lands in telemetry.

        Never consumes the caller's buffers: the caller owns them and may
        hold other references, so when the shared compiled step donates
        (``Engine(donate=True)``, ``run``'s optimization) this call copies
        params/momentum first (``protected_call``) instead of compiling a
        second non-donating executable."""
        if not self.strategy.supports_step:
            raise ValueError(
                f"strategy {self.strategy.name!r} has no per-round step; "
                "use Engine.run")
        b = jax.tree.leaves(batch)[0].shape[0]
        built = self._built_step(
            self.strategy, g=self.num_groups, lr=self.lr,
            momentum=self.momentum,
            per_group_batch=self._per_group_batch(self.num_groups, b))
        self._annotate_buckets(built, params)
        with self.tracer.span("engine.step", g=self.num_groups,
                              mode=built.mode):
            t0 = timing.monotonic()
            params, mom, loss = built.protected_call(params, mom, batch)
            jax.block_until_ready(loss)
            self.telemetry.record(step_s=timing.monotonic() - t0)
        return params, mom, loss

    def _annotate_buckets(self, built, params) -> None:
        """One-time per built step: emit an ``exchange.bucket`` instant
        per gradient slab of the overlapped SPMD exchange (bytes, leaf
        count, head-ness), so the trace shows the collective layout the
        compiled step executes. The layout is host-computable from the
        parameter tree — the collectives themselves run inside jit, where
        host spans cannot reach."""
        if not self.tracer.enabled or getattr(built, "buckets_annotated",
                                              False):
            return
        built.buckets_annotated = True
        if built.mode != "spmd" or self.bucket_bytes <= 0:
            return
        from repro.core.async_sgd import head_mask_tree
        from repro.engine.buckets import assign_buckets
        leaves, tree = jax.tree.flatten(params)
        mask = tree.flatten_up_to(head_mask_tree(params, self.head_filter))
        for i, b in enumerate(assign_buckets(leaves, mask,
                                             self.bucket_bytes)):
            self.tracer.instant("exchange.bucket", bucket=i,
                                bytes=b.nbytes, leaves=len(b.indices),
                                dtype=b.dtype, head=b.is_head)

    # ------------------------------------------------------------------
    # whole runs
    # ------------------------------------------------------------------

    def run(self, params, mom, batches: Iterable, *, steps: int,
            log_every: int = 0, log: Callable = print):
        """Drive ``steps`` rounds from a per-step batch iterator with
        prefetch, telemetry, and checkpoint hooks. For the trace-replay
        strategy the iterator supplies one microbatch per trace commit.

        Returns ``(params, mom, losses)`` (losses: Python floats).
        """
        if self.strategy.name == "trace-replay":
            return self._run_replay(params, mom, batches, steps=steps,
                                    log_every=log_every, log=log)
        if self.donate:
            # the loop's donated buffers must be the engine's own: copy the
            # caller's initial params/momentum once so the first step's
            # donation can't delete arrays the caller still holds
            params = jax.tree.map(jnp.copy, params)
            mom = jax.tree.map(jnp.copy, mom)
        tracer = self.tracer
        losses = []
        loss_series = self.telemetry.registry.series("loss")
        it = prefetch(iter(batches), depth=self.prefetch_depth,
                      tracer=tracer, metrics=self.telemetry.registry)
        with tracer.span("engine.run", strategy=self.strategy.name,
                         g=self.num_groups, steps=steps):
            t_prev = timing.monotonic()
            for i in range(steps):
                with tracer.span("engine.data_wait", step=i):
                    batch = next(it, _END)
                if batch is _END:
                    break
                t_ready = timing.monotonic()
                b = jax.tree.leaves(batch)[0].shape[0]
                built = self._built_step(
                    self.strategy, g=self.num_groups, lr=self.lr,
                    momentum=self.momentum,
                    per_group_batch=self._per_group_batch(self.num_groups,
                                                          b))
                self._annotate_buckets(built, params)
                with tracer.span("engine.step", step=i, mode=built.mode):
                    with tracer.span("engine.dispatch"):
                        params, mom, loss = built(params, mom, batch)
                    with tracer.span("engine.block_until_ready"):
                        # syncs: step wall ends here
                        losses.append(float(loss))
                t_done = timing.monotonic()
                self.telemetry.record(step_s=t_done - t_ready,
                                      data_s=t_ready - t_prev)
                loss_series.append(losses[-1], step=i)
                t_prev = t_done
                if log_every and i % log_every == 0:
                    log(f"step {i:5d} loss {losses[-1]:.4f} "
                        f"({(t_done - t_ready) * 1e3:.0f} ms/it)")
                self._maybe_checkpoint(i + 1, params, mom)
        return params, mom, losses

    def replay(self, params, batches, *, steps: Optional[int] = None):
        """Execute the engine's trace along already-stacked ``batches``
        (leaves (T, ...), one microbatch per commit). Returns
        ``(final_params, losses (T,) ndarray)``; wall time lands in
        telemetry. ``Engine.run`` wraps this for per-step iterators."""
        trace = self.trace
        if trace is None:
            raise ValueError("strategy 'trace-replay' needs Engine(trace=...)")
        if steps is not None:
            trace = trace.truncate(steps)
        if len(trace) == 0:
            raise ValueError("trace has no commits to replay "
                             f"(after truncation to {steps})")
        # staleness-depth stream: the per-commit read-to-commit distance
        # the replay executes — the asynchrony the trace view renders
        reg = self.telemetry.registry
        stale = reg.series("staleness")
        for t, s in enumerate(trace.staleness):
            stale.append(float(s), step=t)
        reg.gauge("replay_max_staleness").set(trace.max_staleness)
        reg.counter("replay_commits").inc(len(trace))
        with self.tracer.span("engine.replay", commits=len(trace),
                              impl=self.replay_impl,
                              num_groups=trace.num_groups):
            t0 = timing.monotonic()
            final, losses, _ = self.strategy.replay(self, params, batches,
                                                    trace=trace)
            self.telemetry.record(step_s=timing.monotonic() - t0)
        return final, np.asarray(losses)

    def _run_replay(self, params, mom, batches, *, steps, log_every, log):
        del mom     # replay owns its momentum state (zeros at trace start)
        if self.trace is None:
            raise ValueError("strategy 'trace-replay' needs Engine(trace=...)")
        T = min(steps, len(self.trace))
        if T == 0:
            raise ValueError("trace has no commits to replay "
                             f"(after truncation to {steps})")
        collected = []
        for i, batch in enumerate(batches):
            if i >= T:
                break
            collected.append(batch)
        if len(collected) < T:
            raise ValueError(f"trace has {T} commits but the batch stream "
                             f"ended after {len(collected)}")
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *collected)
        final, losses = self.replay(params, stacked, steps=T)
        dt = self.telemetry.step_s[-1]
        if log_every:
            for i in range(0, T, log_every):
                log(f"commit {i:5d} loss {float(losses[i]):.4f}")
            log(f"replayed {T} commits in {dt:.2f}s "
                f"({dt / T * 1e3:.0f} ms/commit, impl={self.replay_impl})")
        new_mom = jax.tree.map(jnp.zeros_like, params)
        return final, new_mom, [float(x) for x in losses]

    def _maybe_checkpoint(self, step_no: int, params, mom) -> None:
        if not self.checkpoint_dir or not self.checkpoint_every:
            return
        if step_no % self.checkpoint_every:
            return
        from repro.checkpoint import checkpointing as CK   # lazy
        with self.tracer.span("engine.checkpoint", step=step_no):
            CK.save(f"{self.checkpoint_dir}/ckpt_{step_no:07d}",
                    {"params": params, "mom": mom}, step=step_no)
        self.telemetry.registry.counter("checkpoints").inc()

    # ------------------------------------------------------------------
    # Algorithm-1 Runner protocol
    # ------------------------------------------------------------------

    def __call__(self, state, *, g: int, mu: float, eta: float, steps: int,
                 probe: bool) -> Tuple[object, np.ndarray]:
        """``Runner`` protocol (``core.auto_optimizer``): run ``steps`` at
        (g, mu, eta) from ``state = (params, step_counter)``. Probe runs
        restart from the same checkpoint and do not advance the stream key
        schedule (paper App E-C)."""
        if not self.strategy.supports_runner:
            raise ValueError(
                f"strategy {self.strategy.name!r} is not a Runner substrate")
        if self.sample_batches is None or self.batch_size is None:
            raise ValueError("the Runner protocol needs Engine("
                             "sample_batches=..., batch_size=...)")
        params, t0 = state
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                 t0 + (1 if probe else 0))
        batches = self.sample_batches(key, steps, self.batch_size)
        final, losses = self.strategy.run_stacked(
            self, params, batches, g=g, lr=eta, momentum=mu)
        if probe:
            return state, losses
        return (final, t0 + steps), losses

    # ------------------------------------------------------------------
    # telemetry -> cluster calibration
    # ------------------------------------------------------------------

    def profile(self, params, mom, batch, *, warmup: int = 1,
                iters: int = 5) -> float:
        """Black-box examples/s of the engine's own jitted step (the
        cluster subsystem's ``profile_device`` contract): the probe never
        looks inside the step."""
        from repro.cluster.devices import profile_device   # lazy
        b = jax.tree.leaves(batch)[0].shape[0]
        built = self._built_step(
            self.strategy, g=self.num_groups, lr=self.lr,
            momentum=self.momentum,
            per_group_batch=self._per_group_batch(self.num_groups, b))
        # the probe re-calls the step with the SAME buffers, so it must go
        # through the copy-protected entry when the shared compile donates
        return profile_device(built.protected_call, (params, mom, batch),
                              batch_size=b, warmup=warmup, iters=iters)

    def profiled_spec(self, spec, params, mom, batch, **kw):
        """``DeviceSpec`` with its throughput measured from this engine."""
        import dataclasses as _dc
        return _dc.replace(spec,
                           throughput=self.profile(params, mom, batch, **kw))
