"""Gradient bucketing for the overlapped SPMD grouped step.

The whole-tree step gathers every leaf only after the *entire* backward
pass has produced the full gradient tree, so the gradient exchange and
the backward compute serialize — exactly the HE (hardware-efficiency)
loss the paper's throughput model assumes away. Bucketing cuts the tree
into a handful of flat slabs so each slab's ``all_gather("data")`` +
``all_gather("group")`` depends only on *its own* leaves: XLA's async
collective pair (`all-gather-start`/`-done`) for an early bucket can run
while the remaining backward compute is still producing later buckets.

Assignment is static (shapes/dtypes only, computed at trace time):

- leaves are packed in **reverse flatten order**, matching the order
  reverse-mode AD produces gradients (output-side layers first), so the
  first bucket closes as early in the backward pass as possible;
- a bucket only holds leaves of one (dtype, is_head) class — mixed
  dtypes cannot share a slab without bit-changing casts, and head
  (merged-FC) leaves take different update coefficients;
- buckets close when they reach ``bucket_bytes`` (a target, not a hard
  cap: a single leaf larger than the target still forms one bucket).

Bitwise contract: packing is ``concatenate(ravel(leaf) ...)`` — pure
data movement — and gather/mean on a slab performs the same ascending-k
per-element reduction as the per-leaf gathers it replaces, so the
bucketed step stays bit-identical to ``make_reference_grouped_step``
(pinned by tests/test_engine.py across bucket sizes).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Bucket:
    """One slab: a run of leaves (flat-tree indices) sharing dtype and
    head-ness, packed into a single 1-D gather unit."""
    indices: Tuple[int, ...]          # jax.tree.flatten leaf indices
    shapes: Tuple[Tuple[int, ...], ...]
    dtype: str                        # canonical dtype name, hashable
    is_head: bool

    @property
    def sizes(self) -> Tuple[int, ...]:
        return tuple(int(np.prod(s, dtype=np.int64)) for s in self.shapes)

    @property
    def num_elements(self) -> int:
        return sum(self.sizes)

    @property
    def nbytes(self) -> int:
        return self.num_elements * jnp.dtype(self.dtype).itemsize


def assign_buckets(leaves: Sequence, head_flags: Sequence[bool],
                   bucket_bytes: int) -> Tuple[Bucket, ...]:
    """Static bucket assignment over flat leaves (arrays or avals).

    ``leaves``: the flattened parameter/gradient leaves (only ``.shape``
    and ``.dtype`` are read, so tracers and ShapeDtypeStructs work).
    ``head_flags``: parallel flat list of merged-FC head markers.
    ``bucket_bytes``: per-bucket size target; must be > 0 (the caller
    owns the ``bucket_bytes <= 0`` whole-tree arm).
    """
    if bucket_bytes <= 0:
        raise ValueError(f"bucket_bytes must be > 0, got {bucket_bytes}")
    if len(leaves) != len(head_flags):
        raise ValueError(f"{len(leaves)} leaves vs {len(head_flags)} "
                         "head flags")
    buckets: List[Bucket] = []
    cur_idx: List[int] = []
    cur_shapes: List[Tuple[int, ...]] = []
    cur_key = None          # (dtype_name, is_head)
    cur_bytes = 0

    def close():
        nonlocal cur_idx, cur_shapes, cur_bytes
        if cur_idx:
            buckets.append(Bucket(indices=tuple(cur_idx),
                                  shapes=tuple(cur_shapes),
                                  dtype=cur_key[0], is_head=cur_key[1]))
        cur_idx, cur_shapes, cur_bytes = [], [], 0

    # reverse flatten order = backward production order (see module doc)
    for i in reversed(range(len(leaves))):
        leaf = leaves[i]
        key = (jnp.dtype(leaf.dtype).name, bool(head_flags[i]))
        nbytes = int(np.prod(leaf.shape, dtype=np.int64)) \
            * jnp.dtype(leaf.dtype).itemsize
        if cur_key != key or (cur_idx and cur_bytes + nbytes > bucket_bytes):
            close()
            cur_key = key
        cur_idx.append(i)
        cur_shapes.append(tuple(int(d) for d in leaf.shape))
        cur_bytes += nbytes
    close()
    return tuple(buckets)


def pack_bucket(bucket: Bucket, flat_leaves: Sequence) -> jax.Array:
    """Concatenate the bucket's leaves (raveled) into one (n,) slab —
    pure data movement, no arithmetic."""
    parts = [flat_leaves[i].reshape(-1) for i in bucket.indices]
    return parts[0] if len(parts) == 1 else jnp.concatenate(parts)


def unpack_bucket(bucket: Bucket, slab: jax.Array) -> List[jax.Array]:
    """Split an updated slab back into leaf arrays, in ``bucket.indices``
    order. ``slab`` is (n,) or (g, n) — leading axes are preserved, so a
    gathered (g, n) slab unpacks to per-leaf (g, *shape) stacks."""
    lead = slab.shape[:-1]
    out, off = [], 0
    for shape, size in zip(bucket.shapes, bucket.sizes):
        out.append(slab[..., off:off + size].reshape(lead + shape))
        off += size
    if off != slab.shape[-1]:
        raise ValueError(f"slab has {slab.shape[-1]} elements, bucket "
                         f"expects {off}")
    return out
