"""Monotonic step timing + per-step telemetry.

One clock for the whole repo: ``monotonic()`` (``time.perf_counter``) —
the scattered ``time.time()`` spans the old drivers used were (a) not
monotonic under clock adjustments and (b) wrapped the *whole* iteration,
so host-side data generation and prefetch waits were billed to the device
step. ``Telemetry`` separates the two: ``data_s`` is the time the loop
spent waiting for the next batch, ``step_s`` the dispatch-to-sync time of
the device step itself.

``Telemetry`` is a thin facade over ``repro.obs.metrics.MetricRegistry``:
``record()`` appends to the registry's ``step_s`` / ``data_wait_s``
series (the same stream ``train.py --metrics-out`` sinks to JSONL and
``obs.chrome_trace`` plots), and the legacy accessors (``step_s``,
``median_step_s``, ``throughput``, ``summary``) read straight out of it —
one stream, two views. The step-time stream is what the cluster
subsystem calibrates from: ``Telemetry.throughput()`` is the black-box
examples/s measurement that ``cluster.devices`` turns into a measured
``DeviceSpec`` (see ``spec_from_telemetry``); its ``window`` argument
restricts the estimate to the most recent steps — the time-varying
recalibration hook online ``rebalance()`` consumes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

from repro.obs.metrics import MetricRegistry


def monotonic() -> float:
    """The repo's one wall-clock: monotonic, sub-microsecond resolution."""
    return time.perf_counter()


@dataclasses.dataclass(frozen=True)
class TimeStats:
    """min + median + IQR of a repeated measurement. Median alone cannot
    distinguish a real effect from noise on a shared-CPU box (the
    non-monotonic g=2 vs g=4 rows in early BENCH_engine.json); min is the
    noise-robust point estimate, IQR the spread certificate."""
    min_s: float
    median_s: float
    iqr_s: float
    iters: int

    def row(self, scale: float = 1e6) -> dict:
        """JSON-friendly dict (default unit: microseconds)."""
        return {"min_us": self.min_s * scale,
                "median_us": self.median_s * scale,
                "iqr_us": self.iqr_s * scale,
                "iters": self.iters}


def stats_of(samples: Sequence[float]) -> TimeStats:
    if not samples:
        raise ValueError("no samples")
    xs = sorted(samples)
    n = len(xs)

    def q(p: float) -> float:
        # linear-interpolated quantile (numpy default), dependency-free
        i = p * (n - 1)
        lo = int(i)
        hi = min(lo + 1, n - 1)
        return xs[lo] + (i - lo) * (xs[hi] - xs[lo])

    return TimeStats(min_s=xs[0], median_s=q(0.5), iqr_s=q(0.75) - q(0.25),
                     iters=n)


def probe(fn: Callable[[], object], *, warmup: int = 1,
          iters: int = 5) -> TimeStats:
    """Time ``fn()`` (blocking on its result) ``iters`` times after
    ``warmup`` untimed calls that absorb jit compilation. The repo's one
    measurement primitive: benchmarks/_timeit and the conv-tile autotuner
    both delegate here. Emits one ``timing.probe`` span (attrs carry the
    resulting stats) when a tracer is installed; the span wraps the whole
    probe so the timed region itself is untouched."""
    import jax

    from repro.obs import spans
    with spans.span("timing.probe", warmup=warmup, iters=iters) as sp:
        for _ in range(warmup):
            jax.block_until_ready(fn())
        samples = []
        for _ in range(iters):
            t0 = monotonic()
            jax.block_until_ready(fn())
            samples.append(monotonic() - t0)
        stats = stats_of(samples)
        sp.set(min_us=stats.min_s * 1e6, median_us=stats.median_s * 1e6)
    return stats


class Telemetry:
    """Per-step wall-clock record of an engine run — a facade over an
    ``obs.metrics.MetricRegistry`` (module docstring).

    ``record(step_s, data_s)`` appends one step to the registry's
    ``step_s`` / ``data_wait_s`` series. The first ``skip`` steps
    (default 1) are excluded from the aggregate statistics — they absorb
    jit compilation, which the old one-span ``time.time()`` measurements
    conflated with steady-state execution.
    """

    def __init__(self, skip: int = 1,
                 registry: Optional[MetricRegistry] = None):
        if skip < 0:
            raise ValueError("skip must be >= 0")
        self.skip = skip
        self.registry = registry if registry is not None else MetricRegistry()
        self._step = self.registry.series("step_s")
        self._data = self.registry.series("data_wait_s")

    @property
    def step_s(self) -> List[float]:
        """Per-step device wall times (live view of the registry series)."""
        return self._step.values

    @property
    def data_s(self) -> List[float]:
        """Per-step host data waits (live view of the registry series)."""
        return self._data.values

    @property
    def notes(self) -> List[str]:
        return self.registry.notes

    def note(self, msg: str) -> None:
        """Record a configuration observation (e.g. stranded devices when
        the chosen data-parallel width leaves slots idle). Deduplicated —
        resolution decisions repeat every built step."""
        self.registry.note(msg)

    def __len__(self) -> int:
        return len(self._step)

    def record(self, step_s: float, data_s: float = 0.0) -> None:
        step = len(self._step)
        self._step.append(float(step_s), step=step)
        self._data.append(float(data_s), step=step)

    def _steady(self, window: Optional[int] = None) -> List[float]:
        vals = self._step.values
        steady = vals[self.skip:] if len(vals) > self.skip else list(vals)
        if window is not None and window > 0:
            steady = steady[-window:]
        return steady

    def median_step_s(self, window: Optional[int] = None) -> float:
        """Median steady step time — the interpolated ``stats_of`` median,
        the same estimator every BENCH row and the planner calibration
        use (the old ``sorted[n//2]`` upper-median disagreed with them on
        even-length samples). ``window`` restricts to the most recent N
        steady steps (drift-aware recalibration)."""
        steady = self._steady(window)
        if not steady:
            raise ValueError("no steps recorded")
        return stats_of(steady).median_s

    def mean_step_s(self) -> float:
        steady = self._steady()
        if not steady:
            raise ValueError("no steps recorded")
        return sum(steady) / len(steady)

    def stats(self, window: Optional[int] = None) -> TimeStats:
        """min/median/IQR over the steady-state step times (``skip``
        applied) — what the BENCH_*.json emitters record."""
        steady = self._steady(window)
        if not steady:
            raise ValueError("no steps recorded")
        return stats_of(steady)

    def throughput(self, batch_size: int,
                   window: Optional[int] = None) -> float:
        """Black-box examples/s over the steady-state steps — the number
        ``cluster.devices`` / the planner calibrate from. ``window``
        estimates from only the last N steps (time-varying clusters)."""
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        return batch_size / self.median_step_s(window)

    def drift(self, window: int) -> float:
        """Recent-to-overall median step-time ratio: > 1 means the run is
        slowing down (straggler, thermal, contention), < 1 speeding up.
        The scalar trigger for online re-planning (ROADMAP item 3)."""
        if window < 1:
            raise ValueError("window must be >= 1")
        return self.median_step_s(window) / self.median_step_s()

    def summary(self, batch_size: Optional[int] = None) -> dict:
        data = self._data.values
        out = {
            "steps": len(self._step),
            "median_step_ms": self.median_step_s() * 1e3,
            "mean_step_ms": self.mean_step_s() * 1e3,
            "data_wait_ms": (sum(data[self.skip:])
                             / max(1, len(data) - self.skip)) * 1e3,
        }
        if batch_size is not None:
            out["examples_per_s"] = self.throughput(batch_size)
        if self.notes:
            out["notes"] = list(self.notes)
        return out
