"""Monotonic step timing + per-step telemetry.

One clock for the whole repo: ``monotonic()`` (``time.perf_counter``) —
the scattered ``time.time()`` spans the old drivers used were (a) not
monotonic under clock adjustments and (b) wrapped the *whole* iteration,
so host-side data generation and prefetch waits were billed to the device
step. ``Telemetry`` separates the two: ``data_s`` is the time the loop
spent waiting for the next batch, ``step_s`` the dispatch-to-sync time of
the device step itself.

The step-time stream is what the cluster subsystem calibrates from:
``Telemetry.throughput()`` is the black-box examples/s measurement that
``cluster.devices`` turns into a measured ``DeviceSpec`` (see
``spec_from_telemetry``), closing the loop between the engine and the
time-to-convergence planner.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence


def monotonic() -> float:
    """The repo's one wall-clock: monotonic, sub-microsecond resolution."""
    return time.perf_counter()


@dataclasses.dataclass(frozen=True)
class TimeStats:
    """min + median + IQR of a repeated measurement. Median alone cannot
    distinguish a real effect from noise on a shared-CPU box (the
    non-monotonic g=2 vs g=4 rows in early BENCH_engine.json); min is the
    noise-robust point estimate, IQR the spread certificate."""
    min_s: float
    median_s: float
    iqr_s: float
    iters: int

    def row(self, scale: float = 1e6) -> dict:
        """JSON-friendly dict (default unit: microseconds)."""
        return {"min_us": self.min_s * scale,
                "median_us": self.median_s * scale,
                "iqr_us": self.iqr_s * scale,
                "iters": self.iters}


def stats_of(samples: Sequence[float]) -> TimeStats:
    if not samples:
        raise ValueError("no samples")
    xs = sorted(samples)
    n = len(xs)

    def q(p: float) -> float:
        # linear-interpolated quantile (numpy default), dependency-free
        i = p * (n - 1)
        lo = int(i)
        hi = min(lo + 1, n - 1)
        return xs[lo] + (i - lo) * (xs[hi] - xs[lo])

    return TimeStats(min_s=xs[0], median_s=q(0.5), iqr_s=q(0.75) - q(0.25),
                     iters=n)


def probe(fn: Callable[[], object], *, warmup: int = 1,
          iters: int = 5) -> TimeStats:
    """Time ``fn()`` (blocking on its result) ``iters`` times after
    ``warmup`` untimed calls that absorb jit compilation. The repo's one
    measurement primitive: benchmarks/_timeit and the conv-tile autotuner
    both delegate here."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn())
    samples = []
    for _ in range(iters):
        t0 = monotonic()
        jax.block_until_ready(fn())
        samples.append(monotonic() - t0)
    return stats_of(samples)


class Telemetry:
    """Per-step wall-clock record of an engine run.

    ``record(step_s, data_s)`` appends one step. The first ``skip`` steps
    (default 1) are excluded from the aggregate statistics — they absorb
    jit compilation, which the old one-span ``time.time()`` measurements
    conflated with steady-state execution.
    """

    def __init__(self, skip: int = 1):
        if skip < 0:
            raise ValueError("skip must be >= 0")
        self.skip = skip
        self.step_s: List[float] = []
        self.data_s: List[float] = []
        self.notes: List[str] = []

    def note(self, msg: str) -> None:
        """Record a configuration observation (e.g. stranded devices when
        the chosen data-parallel width leaves slots idle). Deduplicated —
        resolution decisions repeat every built step."""
        if msg not in self.notes:
            self.notes.append(str(msg))

    def __len__(self) -> int:
        return len(self.step_s)

    def record(self, step_s: float, data_s: float = 0.0) -> None:
        self.step_s.append(float(step_s))
        self.data_s.append(float(data_s))

    def _steady(self) -> List[float]:
        return self.step_s[self.skip:] if len(self.step_s) > self.skip \
            else self.step_s

    def median_step_s(self) -> float:
        steady = sorted(self._steady())
        if not steady:
            raise ValueError("no steps recorded")
        return steady[len(steady) // 2]

    def mean_step_s(self) -> float:
        steady = self._steady()
        if not steady:
            raise ValueError("no steps recorded")
        return sum(steady) / len(steady)

    def stats(self) -> TimeStats:
        """min/median/IQR over the steady-state step times (``skip``
        applied) — what the BENCH_*.json emitters record."""
        return stats_of(self._steady())

    def throughput(self, batch_size: int) -> float:
        """Black-box examples/s over the steady-state steps — the number
        ``cluster.devices`` / the planner calibrate from."""
        if batch_size < 1:
            raise ValueError("batch_size must be >= 1")
        return batch_size / self.median_step_s()

    def summary(self, batch_size: Optional[int] = None) -> dict:
        out = {
            "steps": len(self.step_s),
            "median_step_ms": self.median_step_s() * 1e3,
            "mean_step_ms": self.mean_step_s() * 1e3,
            "data_wait_ms": (sum(self.data_s[self.skip:])
                             / max(1, len(self.data_s) - self.skip)) * 1e3,
        }
        if batch_size is not None:
            out["examples_per_s"] = self.throughput(batch_size)
        if self.notes:
            out["notes"] = list(self.notes)
        return out
