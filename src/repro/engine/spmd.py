"""Mesh-sharded grouped step — the paper's compute groups as real SPMD.

The mesh is a ``("group", "data")`` split of the device pool: g groups of
k devices each (``launch.mesh.make_group_mesh``). The global batch is
sharded over both axes, every device computes the gradient of its own
microbatch shard, and the per-group gradient is the mean of the group's k
shard gradients — synchronous data parallelism *within* a group, the
round-robin staleness-0..g-1 grouped update *across* groups (applied
replicated on every device, so parameters never diverge).

Reproducibility contract (pinned by ``tests/test_engine.py``): the
cross-device combination uses ``all_gather`` + a *local* mean on every
device instead of ``psum``. A psum's reduction grouping is backend-chosen
and does not bit-match a single-device reduction; gathering moves bits
unchanged, and the local mean is then the very same reduction the
single-device reference performs. The cost is an O(k) instead of
O(log k) gradient exchange — at the CPU-test and small-cluster scales the
engine targets, bitwise run-anywhere reproducibility is worth more than
the bandwidth (the production dry-run path keeps its psum-based
GSPMD lowering).

``make_reference_grouped_step`` is the single-device twin: ``lax.map``
over the same (g, k) shard structure — unbatched per-shard gradients in
shard order, identical means, identical update — so the SPMD step must
bit-match it leaf for leaf. (A vmap-batched gradient does NOT bit-match
an unbatched one for all models — scatter-add ordering in embedding
backward passes differs — which is why the reference maps over shards
instead of vmapping them.)
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.async_sgd import apply_grouped_update, head_mask_tree


def choose_data_parallel(per_group_batch: int, max_k: int) -> int:
    """Largest within-group data-parallel width k <= max_k that divides the
    per-group microbatch."""
    if per_group_batch < 1 or max_k < 1:
        return 1
    for k in range(min(max_k, per_group_batch), 0, -1):
        if per_group_batch % k == 0:
            return k
    return 1


def device_batch_split(group_batch, k: int):
    """(g, b, ...) leaves -> (g, k, b/k, ...): one shard per mesh device."""
    def split(x):
        g, b = x.shape[0], x.shape[1]
        if b % k:
            raise ValueError(f"per-group batch {b} not divisible by k={k}")
        return x.reshape(g, k, b // k, *x.shape[2:])
    return jax.tree.map(split, group_batch)


def make_spmd_grouped_step(loss_fn: Callable, mesh: Mesh, *, lr: float,
                           momentum: float, weight_decay: float = 0.0,
                           strategy: str = "fused",
                           head_filter: Optional[Callable] = None,
                           group_weights: Optional[Sequence[float]] = None,
                           update_impl: str = "xla",
                           interpret: Optional[bool] = None):
    """Build the mesh-sharded ``step(params, mom, device_batch)``.

    ``device_batch`` leaves carry a leading (g, k, b/k) layout
    (``device_batch_split``); params/momentum enter replicated and leave
    replicated — the grouped update runs identically on every device from
    the all-gathered (g, ...) gradient stack. Returns
    ``(params, mom, losses)`` with ``losses`` the (g, k) per-shard loss
    array — the scalar mean is taken on the host (deterministic float64)
    so the reported loss bit-matches the reference path too, instead of
    depending on how XLA fuses the final reduction.
    """
    g, k = mesh.shape["group"], mesh.shape["data"]

    def step(params, mom_buf, dbatch):
        head_mask = head_mask_tree(params, head_filter)

        def shard_fn(p, v, bt):
            local = jax.tree.map(lambda t: t[0, 0], bt)   # this device's shard
            loss, grad = jax.value_and_grad(loss_fn)(p, local)
            # within-group sync data parallelism: gather the group's k shard
            # gradients (bit-exact data movement), mean locally
            grad = jax.tree.map(
                lambda t: jax.lax.all_gather(t, "data").mean(axis=0), grad)
            # across groups: stack the g per-group gradients on every device
            grad = jax.tree.map(
                lambda t: jax.lax.all_gather(t, "group"), grad)
            losses = jax.lax.all_gather(
                jax.lax.all_gather(loss, "data"), "group")     # (g, k)
            p, v = apply_grouped_update(
                p, grad, v, strategy=strategy, lr=lr, momentum=momentum,
                weight_decay=weight_decay, head_mask=head_mask,
                group_weights=group_weights, update_impl=update_impl,
                interpret=interpret)
            return p, v, losses

        return shard_map(
            shard_fn, mesh=mesh, check_rep=False,
            in_specs=(P(), P(), P("group", "data")),
            out_specs=(P(), P(), P()))(params, mom_buf, dbatch)

    step.mesh_shape = (g, k)
    return step


def make_reference_grouped_step(loss_fn: Callable, g: int, k: int, *,
                                lr: float, momentum: float,
                                weight_decay: float = 0.0,
                                strategy: str = "fused",
                                head_filter: Optional[Callable] = None,
                                group_weights: Optional[Sequence[float]] = None,
                                update_impl: str = "xla",
                                interpret: Optional[bool] = None):
    """Single-device reference of the SPMD step: the same (g, k) shard
    structure executed sequentially (``lax.map`` over shards), the same
    shard-mean and update. Bitwise target of ``make_spmd_grouped_step``.
    """
    def step(params, mom_buf, dbatch):
        flat = jax.tree.map(
            lambda t: t.reshape((g * k,) + t.shape[2:]), dbatch)
        losses, grads = jax.lax.map(
            lambda bt: jax.value_and_grad(loss_fn)(params, bt), flat)
        grads = jax.tree.map(
            lambda t: t.reshape((g, k) + t.shape[1:]).mean(axis=1), grads)
        params_n, mom_n = apply_grouped_update(
            params, grads, mom_buf, strategy=strategy, lr=lr,
            momentum=momentum, weight_decay=weight_decay,
            head_mask=head_mask_tree(params, head_filter),
            group_weights=group_weights, update_impl=update_impl,
            interpret=interpret)
        return params_n, mom_n, losses.reshape(g, k)

    step.mesh_shape = (g, k)
    return step


def group_mesh_devices(g: int, k: int):
    """The first g*k local devices as a (g, k) array for mesh construction."""
    devs = jax.devices()
    if len(devs) < g * k:
        raise ValueError(f"need {g * k} devices for a ({g},{k}) group mesh; "
                         f"have {len(devs)} (set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N on CPU)")
    return np.array(devs[:g * k]).reshape(g, k)
