"""Mesh-sharded grouped step — the paper's compute groups as real SPMD.

The mesh is a ``("group", "data", "mp")`` split of the device pool:
g groups of k workers of mp model-parallel devices each
(``launch.mesh.make_group_mesh``). The global batch is sharded over the
first two axes, every worker computes the gradient of its own microbatch
shard, and the per-group gradient is the mean of the group's k shard
gradients — synchronous data parallelism *within* a group, the
round-robin staleness-0..g-1 grouped update *across* groups (applied
replicated on every device, so parameters never diverge).

Model-parallel storage (``mp > 1``): parameters and momentum are STORED
sharded over the "mp" axis per the PartitionSpecs of
``sharding.rules.engine_param_specs`` (explicit regex rules →
TENSOR_PREF name table → auto-derived trailing divisible dim). The
compute itself stays full-parameter: each device ``all_gather``s the
full parameters from the mp shards (tiled — pure data movement, so the
gathered bits equal the unsharded bits), runs forward/backward on its
microbatch (replicated across mp), then slices the gradient back to its
own mp shard before the exchange. The grouped update is elementwise, so
updating the local shard with the shard of the gradient is bitwise the
shard of the full update — which is how sharded ≡ unsharded stays a
BITWISE identity (pinned by tests/test_engine.py at
(g, mp) ∈ {1,2} × {1,2}). Data/group collectives carry 1/mp of the
gradient bytes; ``mp == 1`` traces the exact pre-mp graph (no gather,
no slice, replicated ``P()`` specs).

Reproducibility contract (pinned by ``tests/test_engine.py``): the
cross-device combination uses ``all_gather`` + a *local* mean on every
device instead of ``psum``. A psum's reduction grouping is backend-chosen
and does not bit-match a single-device reduction; gathering moves bits
unchanged, and the local mean is then the very same reduction the
single-device reference performs. The cost is an O(k) instead of
O(log k) gradient exchange — at the CPU-test and small-cluster scales the
engine targets, bitwise run-anywhere reproducibility is worth more than
the bandwidth (the production dry-run path keeps its psum-based
GSPMD lowering).

Overlapped bucketed exchange (``bucket_bytes > 0``, the default): the
gradient tree is cut into flat slabs (``engine.buckets``) and each slab's
``all_gather("data")`` + ``all_gather("group")`` depends only on its own
leaves, so XLA's async collective pairs start as soon as a bucket's last
gradient is produced and run concurrently with the rest of the backward
pass — the exchange comes off the critical path instead of serializing
after it. The closed-form grouped update is fused into each bucket's
gather epilogue (``kernels.fused_update.fused_bucket_update`` on the
slabs). Bucketing only *reorders independent gathers* and packs leaves by
pure data movement, so the result stays bitwise equal to the whole-tree
step and to ``make_reference_grouped_step``. ``bucket_bytes = 0`` keeps
the legacy whole-tree arm (one gather pair per leaf, applied after the
full backward) — the head-to-head baseline in ``benchmarks/run.py``.

Donation audit: every parameter/momentum output carries an additive
``- tie`` term where ``tie = (0.0 * (loss + sum_buckets sum(raw_grads)))²``
— always ``+0.0`` for finite inputs (squaring kills a possible ``-0.0``,
and subtracting ``+0.0`` is a bitwise identity for every float *including*
``-0.0``), yet never constant-foldable because it propagates NaN/Inf.
The term gives XLA's copy-insertion pass an *arithmetic* dependency from
every reader of the round-start parameters (the backward pass and the
loss) to every parameter write, so the donated input buffers can be
updated in place: the compiled donating step contains no parameter-sized
``copy`` instructions (pinned by tests/test_engine.py). A plain
``optimization_barrier`` does not work here — XLA CPU's copy elision
ignores barrier-induced ordering, and ordering paths that run through
async collective pairs get no credit either, which is why the tie is
computed from the *raw pre-gather* gradient slabs.

``make_reference_grouped_step`` is the single-device twin: ``lax.map``
over the same (g, k) shard structure — unbatched per-shard gradients in
shard order, identical means, identical update — so the SPMD step must
bit-match it leaf for leaf. (A vmap-batched gradient does NOT bit-match
an unbatched one for all models — scatter-add ordering in embedding
backward passes differs — which is why the reference maps over shards
instead of vmapping them.)
"""
from __future__ import annotations

import warnings
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.async_sgd import apply_grouped_update, head_mask_tree
from repro.engine.buckets import assign_buckets, pack_bucket, unpack_bucket
from repro.kernels.fused_update.ops import fused_bucket_update
from repro.optim.closed_form import grouped_coeffs, head_coeffs

#: default per-bucket slab size target (bytes) for the overlapped
#: exchange; 0 selects the legacy whole-tree arm
DEFAULT_BUCKET_BYTES = 4 << 20


class StrandedDevicesWarning(UserWarning):
    """The chosen within-group width k leaves device slots idle because
    nothing larger divides the per-group microbatch."""


def choose_data_parallel(per_group_batch: int, max_k: int, *,
                         warn: bool = True) -> int:
    """Largest within-group data-parallel width k <= max_k that divides the
    per-group microbatch. Falls back to k=1 when nothing divides; any
    k < max_k strands ``max_k - k`` device slots per group — warned here
    (``StrandedDevicesWarning``) and surfaced in ``Engine`` telemetry."""
    if per_group_batch < 1 or max_k < 1:
        return 1
    k = 1
    for cand in range(min(max_k, per_group_batch), 0, -1):
        if per_group_batch % cand == 0:
            k = cand
            break
    if warn and k < max_k:
        warnings.warn(StrandedDevicesWarning(
            f"per-group batch {per_group_batch} admits data-parallel "
            f"width k={k} < {max_k}: {max_k - k} device slot(s) per group "
            "stranded (pick a batch divisible by the per-group device "
            "count to use the full mesh)"), stacklevel=2)
    return k


def device_batch_split(group_batch, k: int):
    """(g, b, ...) leaves -> (g, k, b/k, ...): one shard per mesh device."""
    def split(x):
        g, b = x.shape[0], x.shape[1]
        if b % k:
            raise ValueError(f"per-group batch {b} not divisible by k={k}")
        return x.reshape(g, k, b // k, *x.shape[2:])
    return jax.tree.map(split, group_batch)


def _donation_tie(loss, raw_slabs):
    """The ``+0.0`` ordering term of the donation audit (module doc):
    arithmetically depends on the loss and every raw pre-gather gradient,
    is exactly ``+0.0`` for finite inputs, and propagates NaN/Inf (so XLA
    cannot fold it away)."""
    acc = loss.astype(jnp.float32)
    for slab in raw_slabs:
        acc = acc + jnp.sum(slab).astype(jnp.float32)
    t = jnp.float32(0.0) * acc
    return t * t          # squaring forces +0.0 (never -0.0)


def make_spmd_grouped_step(loss_fn: Callable, mesh: Mesh, *, lr: float,
                           momentum: float, weight_decay: float = 0.0,
                           strategy: str = "fused",
                           head_filter: Optional[Callable] = None,
                           group_weights: Optional[Sequence[float]] = None,
                           update_impl: str = "xla",
                           interpret: Optional[bool] = None,
                           bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                           sharding_rules=None):
    """Build the mesh-sharded ``step(params, mom, device_batch)``.

    ``device_batch`` leaves carry a leading (g, k, b/k) layout
    (``device_batch_split``); params/momentum enter replicated over
    "group"/"data" (and, when the mesh carries an "mp" axis wider than 1,
    sharded over "mp" per ``sharding.rules.engine_param_specs``) and
    leave the same way — the grouped update runs identically on every
    worker from the all-gathered (g, ...) gradient stack. Returns
    ``(params, mom, losses)`` with ``losses`` the (g, k) per-shard loss
    array — the scalar mean is taken on the host (deterministic float64)
    so the reported loss bit-matches the reference path too, instead of
    depending on how XLA fuses the final reduction.

    ``bucket_bytes``: slab size target of the overlapped bucketed
    exchange (module doc); 0 selects the legacy whole-tree arm. With
    ``mp > 1`` the buckets pack the LOCAL gradient shards (slab bytes =
    local shard bytes) and the donation tie is computed from those raw
    local slabs, so the in-place update of the donated shard buffers
    stays ordered against the backward pass.

    ``sharding_rules``: optional explicit ``(regex-path-window, spec)``
    rules forwarded to ``engine_param_specs`` (first match wins; the
    TENSOR_PREF table and auto-derivation cover unmatched leaves).
    """
    g, k = mesh.shape["group"], mesh.shape["data"]
    mp = int(mesh.shape.get("mp", 1))
    bucket_bytes = int(bucket_bytes)
    if strategy == "fused":
        coeffs = grouped_coeffs(g, lr=lr, momentum=momentum,
                                weight_decay=weight_decay,
                                group_weights=group_weights)
        hcoeffs = head_coeffs(g, lr=lr, momentum=momentum,
                              weight_decay=weight_decay,
                              group_weights=group_weights)
    else:
        coeffs = hcoeffs = None

    def step(params, mom_buf, dbatch):
        head_mask = head_mask_tree(params, head_filter)
        tdef = jax.tree.structure(params)
        if mp > 1:
            from repro.sharding.rules import engine_param_specs, spec_mp_dim
            pspecs = engine_param_specs(params, mesh, rules=sharding_rules)
            mp_dims = [spec_mp_dim(s, "mp") for s in
                       jax.tree.leaves(pspecs,
                                       is_leaf=lambda x: isinstance(x, P))]
            param_specs = pspecs
        else:
            mp_dims = None
            param_specs = P()

        def shard_fn(p, v, bt):
            local = jax.tree.map(lambda t: t[0, 0], bt)   # this device's shard
            if mp > 1:
                # gather the full parameters from the mp shards: tiled
                # all_gather is pure data movement, so the gathered leaf
                # is bit-identical to the unsharded one (module doc)
                full_p = jax.tree.unflatten(tdef, [
                    t if d is None else
                    jax.lax.all_gather(t, "mp", axis=d, tiled=True)
                    for t, d in zip(jax.tree.leaves(p), mp_dims)])
            else:
                full_p = p
            loss, grad = jax.value_and_grad(loss_fn)(full_p, local)
            if mp > 1:
                # slice the full-parameter gradient back to this device's
                # mp shard; everything downstream (mean over "data",
                # stack over "group", elementwise update) commutes with
                # the slice, so the updated shard is bitwise the shard of
                # the full update
                i_mp = jax.lax.axis_index("mp")

                def to_shard(t, d):
                    if d is None:
                        return t
                    size = t.shape[d] // mp
                    return jax.lax.dynamic_slice_in_dim(
                        t, i_mp * size, size, axis=d)

                grad = jax.tree.unflatten(tdef, [
                    to_shard(t, d)
                    for t, d in zip(jax.tree.leaves(grad), mp_dims)])
            # one collective for the loss board: a single gather over both
            # mesh axes reshapes bit-identically to the old nested
            # all_gather("data") + all_gather("group") pair
            losses = jax.lax.all_gather(
                loss, ("group", "data")).reshape(g, k)

            if bucket_bytes <= 0:
                # legacy whole-tree arm: gather every leaf after the full
                # backward pass (the pre-overlap baseline, kept for the
                # head-to-head benchmark)
                grad = jax.tree.map(
                    lambda t: jax.lax.all_gather(t, "data").mean(axis=0),
                    grad)
                grad = jax.tree.map(
                    lambda t: jax.lax.all_gather(t, "group"), grad)
                p, v = apply_grouped_update(
                    p, grad, v, strategy=strategy, lr=lr, momentum=momentum,
                    weight_decay=weight_decay, head_mask=head_mask,
                    group_weights=group_weights, update_impl=update_impl,
                    interpret=interpret, coeffs=coeffs, hcoeffs=hcoeffs)
                return p, v, losses

            # ---- overlapped bucketed exchange ----
            flat_g, tree = jax.tree.flatten(grad)
            flat_p = tree.flatten_up_to(p)
            flat_v = tree.flatten_up_to(v)
            flat_m = tree.flatten_up_to(head_mask)
            buckets = assign_buckets(flat_g, flat_m, bucket_bytes)
            raw_slabs = [pack_bucket(b, flat_g) for b in buckets]
            # each bucket's gather pair depends only on its own slab, so
            # the async collectives overlap the remaining backward compute
            gathered = []
            for slab in raw_slabs:
                s = jax.lax.all_gather(slab, "data").mean(axis=0)
                gathered.append(jax.lax.all_gather(s, "group"))   # (g, n)
            # the tie is applied to the update's *inputs* (not outputs):
            # the in-place write the donated buffers receive is the update
            # itself — an output-side tie would leave that write unordered
            # against the forward/backward reads of the old values (the
            # lax.scan carry of the scan strategy exhibits exactly that as
            # a residual copy)
            tie = _donation_tie(loss, raw_slabs)
            flat_p = [t - tie for t in flat_p]
            flat_v = [t - tie for t in flat_v]

            new_p = list(flat_p)
            new_v = list(flat_v)
            if strategy == "fused":
                # update fused into each bucket's gather epilogue, on the
                # flat slabs; unpack (slice+reshape) back to leaves
                for b, gs in zip(buckets, gathered):
                    wn, vn = fused_bucket_update(
                        pack_bucket(b, flat_p), pack_bucket(b, flat_v), gs,
                        coeffs=hcoeffs if b.is_head else coeffs,
                        impl=update_impl, interpret=interpret)
                    for i, w_leaf, v_leaf in zip(b.indices,
                                                 unpack_bucket(b, wn),
                                                 unpack_bucket(b, vn)):
                        new_p[i] = w_leaf
                        new_v[i] = v_leaf
            else:
                # scan strategy: buckets only change the gather
                # granularity — reassemble the per-leaf (g, ...) stacks
                # and run the literal sequential oracle unchanged
                flat_stacks = list(flat_g)
                for b, gs in zip(buckets, gathered):
                    for i, stack in zip(b.indices, unpack_bucket(b, gs)):
                        flat_stacks[i] = stack
                p2, v2 = apply_grouped_update(
                    tree.unflatten(flat_p), tree.unflatten(flat_stacks),
                    tree.unflatten(flat_v), strategy=strategy,
                    lr=lr, momentum=momentum, weight_decay=weight_decay,
                    head_mask=head_mask, group_weights=group_weights,
                    update_impl=update_impl, interpret=interpret)
                new_p = tree.flatten_up_to(p2)
                new_v = tree.flatten_up_to(v2)
            return tree.unflatten(new_p), tree.unflatten(new_v), losses

        return shard_map(
            shard_fn, mesh=mesh, check_rep=False,
            in_specs=(param_specs, param_specs, P("group", "data")),
            out_specs=(param_specs, param_specs, P()))(params, mom_buf,
                                                       dbatch)

    step.mesh_shape = (g, k, mp)
    step.bucket_bytes = bucket_bytes
    return step


def make_reference_grouped_step(loss_fn: Callable, g: int, k: int, *,
                                lr: float, momentum: float,
                                weight_decay: float = 0.0,
                                strategy: str = "fused",
                                head_filter: Optional[Callable] = None,
                                group_weights: Optional[Sequence[float]] = None,
                                update_impl: str = "xla",
                                interpret: Optional[bool] = None,
                                bucket_bytes: int = DEFAULT_BUCKET_BYTES):
    """Single-device reference of the SPMD step: the same (g, k) shard
    structure executed sequentially (``lax.map`` over shards), the same
    shard-mean and update. Bitwise target of ``make_spmd_grouped_step``
    at EVERY ``bucket_bytes`` (accepted and ignored here — bucketing is
    a pure communication-schedule change).
    """
    del bucket_bytes

    def step(params, mom_buf, dbatch):
        flat = jax.tree.map(
            lambda t: t.reshape((g * k,) + t.shape[2:]), dbatch)
        losses, grads = jax.lax.map(
            lambda bt: jax.value_and_grad(loss_fn)(params, bt), flat)
        grads = jax.tree.map(
            lambda t: t.reshape((g, k) + t.shape[1:]).mean(axis=1), grads)
        params_n, mom_n = apply_grouped_update(
            params, grads, mom_buf, strategy=strategy, lr=lr,
            momentum=momentum, weight_decay=weight_decay,
            head_mask=head_mask_tree(params, head_filter),
            group_weights=group_weights, update_impl=update_impl,
            interpret=interpret)
        return params_n, mom_n, losses.reshape(g, k)

    step.mesh_shape = (g, k)
    return step


def group_mesh_devices(g: int, k: int, mp: int = 1):
    """The first g*k*mp local devices as a (g, k, mp) array for mesh
    construction (``launch.mesh.make_group_mesh``)."""
    n = g * k * mp
    devs = jax.devices()
    if len(devs) < n:
        raise ValueError(f"need {n} devices for a ({g},{k},{mp}) group "
                         f"mesh; have {len(devs)} (set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N on CPU)")
    return np.array(devs[:n]).reshape(g, k, mp)
