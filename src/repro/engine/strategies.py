"""Execution-strategy plugins behind ``Engine.step`` / ``Engine.run``.

One registry, four deployable strategies plus the Theorem-1-exact
substrate the statistical-efficiency experiments need:

  sync           g=1 synchronous data-parallel SGD (the grouped step's
                 exact g=1 reduction; pinned to g=1)
  grouped-fused  g async compute groups, closed-form fused update
  grouped-scan   g async compute groups, literal O(g) sequential update
  trace-replay   execute momentum-SGD along a recorded EventTrace
                 (``repro.exec``) — run-level only, no per-round step
  delayed        exact delayed SGD (staleness S=g-1, paper Theorem 1) —
                 the Runner substrate for Algorithm 1 on CPU

A strategy provides ``build_step`` (a jittable per-round step +
host-side batch preparation) and/or ``run_stacked`` (a whole-run driver
over stacked batches, used by the Algorithm-1 Runner protocol).
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import numpy as np

from repro.core.async_sgd import delayed_sgd_run, make_grouped_train_step
from repro.core.compute_groups import group_batch_split
from repro.engine.spmd import (device_batch_split, make_reference_grouped_step,
                               make_spmd_grouped_step)

_REGISTRY: Dict[str, "Strategy"] = {}


def register_strategy(cls):
    inst = cls()
    _REGISTRY[inst.name] = inst
    return cls


def get_strategy(name: str) -> "Strategy":
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown strategy {name!r}; "
                         f"known: {sorted(_REGISTRY)}") from None


def list_strategies():
    return tuple(sorted(_REGISTRY))


class Strategy:
    """Interface. ``supports_step``: has a per-round ``step``;
    ``supports_runner``: usable as the Algorithm-1 Runner substrate."""
    name = "?"
    supports_step = True
    supports_runner = True

    def build_step(self, engine, *, g: int, lr: float, momentum: float,
                   per_group_batch: int, donate: bool):
        raise NotImplementedError(f"{self.name} has no per-round step")

    def run_stacked(self, engine, params, batches, *, g: int, lr: float,
                    momentum: float):
        raise NotImplementedError(f"{self.name} cannot drive a stacked run")


class _BuiltStep:
    """A compiled step + its batch-preparation recipe.

    spmd/reference bodies return per-shard (g, k) losses (their scalar
    mean is backend-fusion-dependent); ``__call__`` reduces them on the
    host in float64 so every mode reports one deterministic scalar.

    One compile serves both donating and non-donating callers: the step
    is jitted once (``donating`` records whether its params/momentum args
    are donated) and callers that do NOT own their buffers go through
    ``protected_call``, which copies them first when the compile donates
    — donation never enters the Engine's compile-cache key."""

    def __init__(self, fn: Callable, raw: Callable, prepare: Callable,
                 mode: str, g: int, k: int, donating: bool = False):
        self.fn = fn              # jitted (params, mom, device_batch)
        self.raw = raw            # un-jitted body (for lax.scan runs)
        self.prepare = prepare    # host: global batch -> device-form batch
        self.mode = mode          # "spmd" | "reference" | "vmap"
        self.g, self.k = g, k
        self.donating = donating  # fn donates its params/momentum args
        self.run_fn = None        # lazily-cached jitted whole-run scan

    @staticmethod
    def scalar_loss(loss):
        if getattr(loss, "ndim", 0) == 0:
            return loss
        return np.asarray(loss, np.float64).mean()

    def __call__(self, params, mom, batch):
        params, mom, loss = self.fn(params, mom, self.prepare(batch))
        return params, mom, self.scalar_loss(loss)

    def protected_call(self, params, mom, batch):
        """Call without consuming ``params``/``mom``: copies them first
        iff the shared compile donates (callers that own their buffers —
        ``Engine.run``'s loop — use ``__call__`` directly)."""
        if self.donating:
            params = jax.tree.map(jax.numpy.copy, params)
            mom = jax.tree.map(jax.numpy.copy, mom)
        return self(params, mom, batch)


class GroupedStrategy(Strategy):
    """g async compute groups; subclasses pick the update application."""
    update = "fused"

    def build_step(self, engine, *, g, lr, momentum, per_group_batch, donate):
        with engine.tracer.span("engine.build_step", strategy=self.name,
                                g=g) as sp:
            mode, k, mesh = engine._resolve_exec(g, per_group_batch)
            sp.set(mode=mode, k=k)
            weights = engine._weights_for(g)
            sizes = engine._sizes_for(g)
            common = dict(lr=lr, momentum=momentum,
                          weight_decay=engine.weight_decay,
                          strategy=self.update,
                          head_filter=engine.head_filter,
                          group_weights=weights,
                          update_impl=engine.update_impl,
                          interpret=engine.interpret)
            if mode == "spmd":
                raw = make_spmd_grouped_step(engine.loss_fn, mesh,
                                             bucket_bytes=engine.bucket_bytes,
                                             sharding_rules=engine.sharding_rules,
                                             **common)
            elif mode == "reference":
                raw = make_reference_grouped_step(engine.loss_fn, g, k,
                                                  **common)
            else:
                raw = make_grouped_train_step(engine.loss_fn, num_groups=g,
                                              **common)

            def prepare(batch):
                gb = group_batch_split(batch, g, sizes=sizes)
                if mode in ("spmd", "reference"):
                    gb = device_batch_split(gb, k)
                return gb

            fn = jax.jit(raw, donate_argnums=(0, 1) if donate else ())
        return _BuiltStep(fn, raw, prepare, mode, g, k, donating=donate)

    def run_stacked(self, engine, params, batches, *, g, lr, momentum):
        b = jax.tree.leaves(batches)[0].shape[1]
        per_group = engine._per_group_batch(g, b)
        # only step.raw / step.run_fn are used below (never the possibly
        # donating step.fn): Algorithm-1 probe runs re-enter with the same
        # parameter buffers, so the whole-run scan stays undonated
        step = engine._built_step(self, g=g, lr=lr, momentum=momentum,
                                  per_group_batch=per_group)
        dbatches = jax.vmap(step.prepare)(batches)
        mom = jax.tree.map(jax.numpy.zeros_like, params)

        # one jitted whole-run scan per built step: Algorithm-1 re-probes
        # the same (g, mu, eta) many times, and a fresh closure per call
        # would retrace the full T-step loop every probe
        run = step.run_fn
        if run is None:
            @jax.jit
            def run(p, v, db):
                def body(carry, bt):
                    p, v = carry
                    p, v, loss = step.raw(p, v, bt)
                    return (p, v), loss
                (p, v), losses = jax.lax.scan(body, (p, v), db)
                return p, v, losses
            step.run_fn = run

        final, _, losses = run(params, mom, dbatches)
        losses = np.asarray(losses)
        if losses.ndim > 1:                    # (T, g, k) per-shard losses
            losses = losses.mean(axis=tuple(range(1, losses.ndim)))
        return final, losses


@register_strategy
class GroupedFusedStrategy(GroupedStrategy):
    name = "grouped-fused"
    update = "fused"


@register_strategy
class GroupedScanStrategy(GroupedStrategy):
    name = "grouped-scan"
    update = "scan"


@register_strategy
class SyncStrategy(GroupedStrategy):
    """Synchronous data-parallel SGD = the grouped step at g=1 (the exact
    reduction ``core.async_sgd`` documents). Pinned to g=1: asking it for
    g>1 is a configuration error, not a silent strategy change."""
    name = "sync"
    update = "fused"

    def _check(self, g):
        if g != 1:
            raise ValueError(f"strategy 'sync' is pinned to g=1, got g={g}; "
                             "use grouped-fused/grouped-scan for g>1")

    def build_step(self, engine, *, g, lr, momentum, per_group_batch, donate):
        self._check(g)
        return super().build_step(engine, g=g, lr=lr, momentum=momentum,
                                  per_group_batch=per_group_batch,
                                  donate=donate)

    def run_stacked(self, engine, params, batches, *, g, lr, momentum):
        self._check(g)
        return super().run_stacked(engine, params, batches, g=g, lr=lr,
                                   momentum=momentum)


@register_strategy
class DelayedStrategy(Strategy):
    """Theorem-1-exact delayed SGD (gradient at W_{t-S}, S=g-1). Carries an
    (S+1)-deep parameter history — the CPU statistical-efficiency
    substrate, and the default Runner behind ``workload.make_runner``."""
    name = "delayed"
    supports_step = False

    def run_stacked(self, engine, params, batches, *, g, lr, momentum):
        final, losses, _ = delayed_sgd_run(
            engine.loss_fn, params, batches, staleness=g - 1, lr=lr,
            momentum=momentum, weight_decay=engine.weight_decay)
        return final, np.asarray(losses)


@register_strategy
class TraceReplayStrategy(Strategy):
    """Execute momentum-SGD along the engine's recorded ``EventTrace``
    (``repro.exec.replay``): one stale commit per trace event instead of
    round-robin rounds. Run-level only — per-commit staleness needs the
    whole schedule, so there is no per-round ``step`` and no Runner."""
    name = "trace-replay"
    supports_step = False
    supports_runner = False

    def replay(self, engine, params, batches, trace=None):
        """``trace`` (e.g. a truncated view) overrides ``engine.trace``."""
        from repro.exec import replay_trace   # lazy: keeps engine light
        trace = engine.trace if trace is None else trace
        if trace is None:
            raise ValueError("strategy 'trace-replay' needs Engine(trace=...)")
        return replay_trace(
            engine.loss_fn, params, batches, trace, lr=engine.lr,
            momentum=engine.momentum, weight_decay=engine.weight_decay,
            impl=engine.replay_impl, depth=engine.replay_depth)
