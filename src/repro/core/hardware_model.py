"""Hardware-efficiency (HE) model — paper §IV-B, adapted to TPU.

    HE(g) = max( t_fc,  (t_conv(k) + t_fc) / g ),   k = N / g
    t_conv(k) = max( t_conv_compute(1)/k , t_conv_network(k) )

Paper's parameter-server network term ``T_n,c * k`` (Ethernet congestion)
becomes, on TPU, the ring reduce-scatter+all-gather time of the backbone
gradients over the group — bandwidth-optimal and ~flat in k:
    t_coll(k) = 2 * bytes * (k-1)/k / ici_bw
(per-chip time; ~2*bytes/ici_bw for large k).

The phase times can be *derived from the compiled dry-run* via
``phase_times_from_roofline`` so the same model that the paper fit with
measurements is fit here from `cost_analysis()` + HLO collective bytes.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    """TPU v5e (the target device of this reproduction)."""
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # bytes/s per chip
    ici_bw: float = 50e9                # bytes/s per link


V5E = TPUSpec()


def collective_time(bytes_per_chip: float, k: int, spec: TPUSpec = V5E) -> float:
    """Ring reduce-scatter + all-gather over a group of size k."""
    if k <= 1:
        return 0.0
    return 2.0 * bytes_per_chip * (k - 1) / k / spec.ici_bw


@dataclasses.dataclass(frozen=True)
class PhaseTimes:
    """One-device phase times (paper's T_c,c / t_fc) + collective volume."""
    t_conv_compute_1: float      # backbone fwd+bwd on ONE device, seconds
    t_fc: float                  # head phase service time, seconds
    conv_grad_bytes: float       # backbone grad bytes (per-chip, for t_coll)


def t_conv(k: int, ph: PhaseTimes, spec: TPUSpec = V5E) -> float:
    """Group-of-k backbone time: compute shrinks /k, collectives overlap
    (paper's max(), §App D-D1)."""
    comp = ph.t_conv_compute_1 / k
    coll = collective_time(ph.conv_grad_bytes, k, spec)
    return max(comp, coll)


def he_time_per_iteration(g: int, n_devices: int, ph: PhaseTimes,
                          spec: TPUSpec = V5E) -> float:
    """Predicted time per iteration for g compute groups (paper HE model)."""
    if n_devices % g:
        raise ValueError(f"g={g} must divide N={n_devices}")
    k = n_devices // g
    return max(ph.t_fc, (t_conv(k, ph, spec) + ph.t_fc) / g)


def fc_saturated(g: int, n_devices: int, ph: PhaseTimes,
                 spec: TPUSpec = V5E) -> bool:
    """Paper's saturation condition: t_conv(k) + t_fc < g * t_fc."""
    k = n_devices // g
    return t_conv(k, ph, spec) + ph.t_fc < g * ph.t_fc


def smallest_saturating_g(n_devices: int, ph: PhaseTimes,
                          spec: TPUSpec = V5E) -> int:
    """Optimizer short-circuit (§App E-C1): start Algorithm 1 at the smallest
    g that saturates the FC server."""
    g = 1
    while g < n_devices:
        if fc_saturated(g, n_devices, ph, spec):
            return g
        g *= 2
    return n_devices


def he_penalty(g: int, n_devices: int, ph: PhaseTimes,
               spec: TPUSpec = V5E) -> float:
    """P_HE(S) = HE(S)/HE(0), normalized to sync (paper App D-D)."""
    return (he_time_per_iteration(g, n_devices, ph, spec)
            / he_time_per_iteration(1, n_devices, ph, spec))


def phase_times_from_roofline(*, backbone_flops: float, head_flops: float,
                              backbone_bytes: float, head_bytes: float,
                              grad_bytes_per_chip: float,
                              spec: TPUSpec = V5E) -> PhaseTimes:
    """Derive the HE model's parameters from compiled-program roofline terms
    (single-chip FLOPs/bytes split between backbone and head phases)."""
    t_conv_1 = max(backbone_flops / spec.peak_flops,
                   backbone_bytes / spec.hbm_bw)
    t_fc = max(head_flops / spec.peak_flops, head_bytes / spec.hbm_bw)
    return PhaseTimes(t_conv_compute_1=t_conv_1, t_fc=t_fc,
                      conv_grad_bytes=grad_bytes_per_chip)
