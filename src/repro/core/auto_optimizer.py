"""Algorithm 1 — Omnivore's automatic optimizer (paper §V-B, App E).

Epoch loop: adaptive grid search over (momentum, learning-rate) at the
current number of compute groups g; if the best explicit momentum is 0,
asynchrony's implicit momentum is already past optimal — halve g and
re-search. Cold start runs synchronously (scale-setting, App E-D), and the
initial g comes from the HE model's FC-saturation short-circuit.

The optimizer is decoupled from the execution substrate through ``Runner``:
    runner(state, *, g, mu, eta, steps, probe) -> (new_state, losses)
so the same Algorithm 1 drives CPU experiments (delayed SGD) and the SPMD
grouped step. The canonical Runner is an execution engine
(``repro.engine.Engine`` — callable with exactly this protocol, built by
``core.workload.make_runner``); any conforming callable works.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import hardware_model as hm

Runner = Callable[..., Tuple[object, np.ndarray]]

DEFAULT_MUS = (0.0, 0.3, 0.6, 0.9)
COLD_START_ETAS = (0.1, 0.01, 0.001, 0.0001, 0.00001)


@dataclasses.dataclass
class Decision:
    phase: str
    g: int
    mu: float
    eta: float
    loss: float


@dataclasses.dataclass
class OptimizerResult:
    state: object
    g: int
    mu: float
    eta: float
    decisions: List[Decision]
    losses: np.ndarray
    mp: int = 1        # model-parallel width of the planned mesh (the
    #                    engine's "mp" axis; from the planner Plan)


def _final_loss(losses, tail: int = 50) -> float:
    arr = np.asarray(losses, dtype=np.float64)
    arr = arr[np.isfinite(arr)]
    if arr.size == 0:
        return float("inf")
    return float(arr[-min(tail, arr.size):].mean())


def grid_search(runner: Runner, state, *, g: int, etas: Sequence[float],
                mus: Sequence[float], probe_steps: int,
                mu_cap: Optional[float] = None, eta_cap_at: Optional[float] = None):
    """Paper App E-C: run each (mu, eta) for probe_steps from the same
    checkpoint; return (mu*, eta*, loss*). Pruning: while eta == eta_last,
    don't search mu above mu_last."""
    best = (None, None, float("inf"))
    for eta in etas:
        for mu in mus:
            if (mu_cap is not None and eta_cap_at is not None
                    and eta == eta_cap_at and mu > mu_cap):
                continue
            _, losses = runner(state, g=g, mu=mu, eta=eta,
                               steps=probe_steps, probe=True)
            fl = _final_loss(losses)
            if np.isfinite(fl) and fl < best[2]:
                best = (mu, eta, fl)
    if best[0] is None:
        raise RuntimeError("all probe configurations diverged")
    # refinement near mu = 0 (paper: "if mu*=0, try 0.1 and 0.2 as well")
    if best[0] == 0.0:
        for mu in (0.1, 0.2):
            _, losses = runner(state, g=g, mu=mu, eta=best[1],
                               steps=probe_steps, probe=True)
            fl = _final_loss(losses)
            if fl < best[2]:
                best = (mu, best[1], fl)
    return best


def cold_start(runner: Runner, state, *, probe_steps: int,
               etas: Sequence[float] = COLD_START_ETAS):
    """Sync (g=1), mu=0.9; sweep eta high->low with early stop (App E-D)."""
    best = (0.9, None, float("inf"))
    prev = float("inf")
    for eta in etas:
        _, losses = runner(state, g=1, mu=0.9, eta=eta,
                           steps=probe_steps, probe=True)
        fl = _final_loss(losses)
        if np.isfinite(fl) and fl < best[2]:
            best = (0.9, eta, fl)
        if np.isfinite(fl) and fl > prev:
            break                          # getting worse: stop early
        prev = fl
    if best[1] is None:
        raise RuntimeError("cold start found no converging learning rate")
    return best


def algorithm1(runner: Runner, state, *, n_devices: int, epochs: int,
               epoch_steps: int, probe_steps: int,
               phase_times: Optional[hm.PhaseTimes] = None,
               g0: Optional[int] = None, plan=None,
               mus: Sequence[float] = DEFAULT_MUS) -> OptimizerResult:
    """Full Algorithm 1 with cold start and HE short-circuit.

    Initial g precedence: explicit ``g0`` > ``plan`` (a
    ``cluster.planner.Plan`` — or anything with a ``.g`` — from the
    heterogeneous time-to-convergence search) > homogeneous ``phase_times``
    FC-saturation short-circuit > fully async (g = N).

    A plan from the 2-D (g, mp) search carries a model-parallel width
    ``plan.mp``; it is validated against the device budget (g*mp <= N),
    passed through on the result (``OptimizerResult.mp``) and fixed for
    the run — Algorithm 1 adapts g (the staleness axis) only, because mp
    moves bytes, not gradients: SE is mp-invariant, so re-searching it
    per epoch would spend probes on a statistically neutral knob.
    """
    decisions: List[Decision] = []
    all_losses: List[np.ndarray] = []
    mp = int(getattr(plan, "mp", 1) or 1) if plan is not None else 1

    # --- cold start: synchronous scale-setting ---
    mu, eta, fl = cold_start(runner, state, probe_steps=probe_steps)
    state, losses = runner(state, g=1, mu=mu, eta=eta, steps=epoch_steps,
                           probe=False)
    all_losses.append(np.asarray(losses))
    decisions.append(Decision("cold", 1, mu, eta, _final_loss(losses)))
    eta_last, mu_last = eta, mu

    # --- initial g: explicit > planner > smallest FC-saturating (App
    # E-C1) > N ---
    if g0 is not None:
        g = g0
    elif plan is not None:
        g = int(plan.g)
        if not 1 <= g * mp <= n_devices:
            raise ValueError(f"plan (g={g}, mp={mp}) infeasible for "
                             f"N={n_devices}")
    elif phase_times is not None:
        g = hm.smallest_saturating_g(n_devices, phase_times)
    else:
        g = n_devices

    for _ in range(epochs):
        etas = (eta_last, eta_last / 10.0)
        mu, eta, fl = grid_search(runner, state, g=g, etas=etas, mus=mus,
                                  probe_steps=probe_steps,
                                  mu_cap=mu_last, eta_cap_at=eta_last)
        while mu == 0.0 and g > 1:
            g //= 2
            mu, eta, fl = grid_search(runner, state, g=g, etas=etas, mus=mus,
                                      probe_steps=probe_steps,
                                      mu_cap=mu_last, eta_cap_at=eta_last)
        state, losses = runner(state, g=g, mu=mu, eta=eta, steps=epoch_steps,
                               probe=False)
        all_losses.append(np.asarray(losses))
        decisions.append(Decision("epoch", g, mu, eta, _final_loss(losses)))
        eta_last, mu_last = eta, mu

    return OptimizerResult(state=state, g=g, mu=mu, eta=eta,
                           decisions=decisions,
                           losses=np.concatenate(all_losses), mp=mp)
