"""Discrete-event simulation of the paper's server architecture (Fig. 5/21):
g compute groups (conv phase, duration t_conv(k)) feeding one merged-FC
server (serial, duration t_fc). Service times optionally exponential —
assumption (A2) of Theorem 1.

Validates (a) the analytic HE model and (b) the staleness distribution that
justifies implicit momentum = 1 - 1/g.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import numpy as np


@dataclasses.dataclass
class SimResult:
    time_per_iteration: float
    iterations: int
    mean_staleness: float
    staleness_hist: np.ndarray


def simulate(*, g: int, t_conv: float, t_fc: float, iters: int = 2000,
             exponential: bool = True, seed: int = 0,
             cv: Optional[float] = None, return_trace: bool = False):
    """Event loop: each group cycles (conv compute -> FC service -> update).
    The FC server is serial; groups queue for it. The model version counter
    increments on every FC completion (update); staleness of an update is
    (#updates between the group's model read and its write) (paper §IV-A).

    ``return_trace=True`` additionally returns the per-commit
    ``repro.exec.trace.EventTrace`` (commit group / read version / time),
    which ``repro.exec.replay`` can execute real SGD along. Recording does
    not touch the RNG stream, so the ``SimResult`` is bit-identical either
    way.
    """
    rng = np.random.default_rng(seed)

    def dur(mean):
        if exponential:
            return rng.exponential(mean)
        if cv:  # lognormal with given coefficient of variation
            sigma = np.sqrt(np.log(1 + cv ** 2))
            return rng.lognormal(np.log(mean) - sigma ** 2 / 2, sigma)
        return mean

    version = 0
    read_version = {i: 0 for i in range(g)}
    staleness = []
    commits = []  # (group, read_version, time) per fc_done
    fc_busy_until = 0.0
    done_time = None
    events = []  # (time, seq, kind, group)
    seq = 0
    for i in range(g):
        heapq.heappush(events, (dur(t_conv), seq, "conv_done", i))
        seq += 1

    completed = 0
    while completed < iters and events:
        t, _, kind, grp = heapq.heappop(events)
        if kind == "conv_done":
            start = max(t, fc_busy_until)
            fin = start + dur(t_fc)
            fc_busy_until = fin
            heapq.heappush(events, (fin, seq, "fc_done", grp))
            seq += 1
        else:  # fc_done: model update commits
            staleness.append(version - read_version[grp])
            commits.append((grp, read_version[grp], t))
            version += 1
            completed += 1
            done_time = t
            read_version[grp] = version     # group re-reads fresh model
            heapq.heappush(events, (t + dur(t_conv), seq, "conv_done", grp))
            seq += 1

    st = np.asarray(staleness[iters // 10:])  # drop warmup
    result = SimResult(time_per_iteration=done_time / completed,
                       iterations=completed,
                       mean_staleness=float(st.mean()),
                       staleness_hist=np.bincount(st, minlength=2 * g))
    if not return_trace:
        return result
    from repro.exec.trace import EventTrace  # local: core must import alone
    grp_a, rv_a, t_a = (np.asarray(c) for c in zip(*commits))
    return result, EventTrace(num_groups=g, group=grp_a, read_version=rv_a,
                              commit_time=t_a)
