"""The paper's primary contribution: compute-group asynchrony with
HE/SE models and the automatic optimizer (Algorithm 1)."""
from repro.core import (async_sgd, auto_optimizer, bayesian, compute_groups,
                        hardware_model, implicit_momentum, queue_sim,
                        stat_model, workload)

__all__ = ["async_sgd", "auto_optimizer", "bayesian", "compute_groups",
           "hardware_model", "implicit_momentum", "queue_sim", "stat_model",
           "workload"]
