"""Compute groups — the paper's execution-strategy axis (§IV-A).

``g`` groups of ``k = N/g`` devices each. Within a group: synchronous
data-parallel SGD over the group's batch. Across groups: asynchronous
round-robin updates (staleness S = g - 1).

On an SPMD TPU mesh the group axis is a split of the data axis:
``data = (group, within_group)``. ``group_batch_split`` reshapes a global
batch so axis 0 enumerates groups.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    num_groups: int               # g
    num_devices: int = 1          # N (conv-phase devices in paper terms)

    def __post_init__(self):
        if self.num_devices % self.num_groups:
            raise ValueError(
                f"g={self.num_groups} must divide N={self.num_devices}")

    @property
    def staleness(self) -> int:  # S
        return self.num_groups - 1

    @property
    def group_size(self) -> int:  # k
        return self.num_devices // self.num_groups

    @property
    def implicit_momentum(self) -> float:
        """Theorem 1: asynchrony contributes momentum 1 - 1/g."""
        return 1.0 - 1.0 / self.num_groups


def group_batch_split(batch, g: int, sizes: Optional[Sequence[int]] = None):
    """Split every leaf (B, ...) into one microbatch per group, axis 0 = g.

    Equal shares (``sizes=None``): reshape (B, ...) -> (g, B/g, ...).

    Unequal shares (``sizes`` from a heterogeneous allocation,
    ``cluster.allocator.Allocation.microbatches``): each group gets its own
    contiguous slice, wrap-filled (examples cycled) to ``max(sizes)`` so all
    microbatches share a shape for the SPMD vmap.

    Wrap-fill bias bound: a group of size ``s`` cycled to ``b = max(sizes)``
    repeats its first ``r = b mod s`` examples once more than the rest, so
    its microbatch mean differs from the true slice mean by exactly

        (r (s - r) / (s b)) * (mean of first r - mean of remaining s - r)

    whose magnitude is at most ``(s / (4 b)) * (max - min)`` over the
    slice — an O(1/b) bias (zero when ``s`` divides ``b``). Cross-group
    weighting must come from ``make_grouped_train_step(group_weights=...)``,
    not from here.
    """
    if sizes is not None:
        sizes = tuple(int(s) for s in sizes)
        if len(sizes) != g:
            raise ValueError(f"need {g} sizes, got {len(sizes)}")
        if any(s < 1 for s in sizes):
            raise ValueError(f"every group needs >= 1 example, got {sizes}")
        if len(set(sizes)) > 1:
            return _group_batch_split_sized(batch, sizes)
        # equal sizes: fall through to the plain reshape

    def split(x):
        b = x.shape[0]
        if sizes is not None and b != sum(sizes):
            raise ValueError(f"batch {b} != sum(sizes)={sum(sizes)}")
        if b % g:
            raise ValueError(f"batch {b} not divisible by g={g}")
        return x.reshape(g, b // g, *x.shape[1:])
    return jax.tree.map(split, batch)


def _group_batch_split_sized(batch, sizes: Sequence[int]):
    """Ragged split stacked to (g, max(sizes), ...) by cycling each group's
    own slice (static gather — sizes are Python ints)."""
    g, total, bmax = len(sizes), sum(sizes), max(sizes)
    offsets = np.cumsum([0] + list(sizes[:-1]))
    idx = np.concatenate([off + (np.arange(bmax) % s)
                          for off, s in zip(offsets, sizes)])

    def split(x):
        if x.shape[0] != total:
            raise ValueError(f"batch {x.shape[0]} != sum(sizes)={total}")
        return x[idx].reshape(g, bmax, *x.shape[1:])
    return jax.tree.map(split, batch)
