"""Compute groups — the paper's execution-strategy axis (§IV-A).

``g`` groups of ``k = N/g`` devices each. Within a group: synchronous
data-parallel SGD over the group's batch. Across groups: asynchronous
round-robin updates (staleness S = g - 1).

On an SPMD TPU mesh the group axis is a split of the data axis:
``data = (group, within_group)``. ``group_batch_split`` reshapes a global
batch so axis 0 enumerates groups.
"""
from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    num_groups: int               # g
    num_devices: int = 1          # N (conv-phase devices in paper terms)

    def __post_init__(self):
        if self.num_devices % self.num_groups:
            raise ValueError(
                f"g={self.num_groups} must divide N={self.num_devices}")

    @property
    def staleness(self) -> int:  # S
        return self.num_groups - 1

    @property
    def group_size(self) -> int:  # k
        return self.num_devices // self.num_groups

    @property
    def implicit_momentum(self) -> float:
        """Theorem 1: asynchrony contributes momentum 1 - 1/g."""
        return 1.0 - 1.0 / self.num_groups


def group_batch_split(batch, g: int):
    """Reshape every leaf (B, ...) -> (g, B/g, ...): one microbatch per group."""
    def split(x):
        b = x.shape[0]
        if b % g:
            raise ValueError(f"batch {b} not divisible by g={g}")
        return x.reshape(g, b // g, *x.shape[1:])
    return jax.tree.map(split, batch)
