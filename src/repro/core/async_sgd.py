"""Asynchronous (stale-gradient) SGD with compute groups.

Two implementations of the paper's execution strategy:

1. ``delayed_sgd_run`` — the Theorem-1-exact object: SGD where the gradient
   applied at step t was evaluated at ``W_{t-S}`` (S = g-1). Used by the
   statistical-efficiency experiments; carries an (S+1)-deep parameter
   history, so it is meant for small models on CPU.

2. ``grouped_train_step`` — the deployable SPMD step: each round, all g
   groups compute gradients at the round-start parameters **in parallel**
   (full hardware utilization on the mesh), then the g updates land with
   staleness 0..g-1 — the paper's Fig. 17(b) round-robin picture.
   ``head_filter`` implements the merged-FC optimization: head params see
   one averaged (zero-staleness) update each round.

   Because all g gradients are evaluated at round-start parameters, the g
   sequential momentum-SGD sub-steps form a linear recurrence with a
   closed-form solution (optim/closed_form.py). The default
   ``strategy="fused"`` applies that closed form in ONE pass over the
   parameters (kernels/fused_update); ``strategy="scan"`` keeps the
   literal O(g) sequential application as the semantic reference.

Both reduce exactly to synchronous data-parallel SGD at g=1.

``repro.exec.replay`` generalizes (1) from one fixed staleness S to
per-commit staleness along an arbitrary recorded ``EventTrace`` (ring-
buffered parameter history); the deterministic round-robin traces reduce
it back to these two implementations — the conformance contract pinned by
``tests/test_exec_replay.py``.
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.fused_update.ops import fused_group_update
from repro.optim.closed_form import (_weight_scales, grouped_coeffs,
                                     head_coeffs)


# ---------------------------------------------------------------------------
# 1. Exact delayed SGD (Theorem-1 semantics), for SE experiments
# ---------------------------------------------------------------------------

def delayed_sgd_run(loss_fn: Callable, params, batches, *, staleness: int,
                    lr: float, momentum: float = 0.0, weight_decay: float = 0.0,
                    record_params: bool = False):
    """Run ``T`` delayed-SGD steps (T = leading dim of ``batches``).

    Update:  V_{t+1} = mu V_t - eta grad(W_{t-S});  W_{t+1} = W_t + V_{t+1}.
    For t < S the oldest available parameters are used (cold history).

    Returns (final_params, losses (T,), params_trace or None).
    """
    S = staleness
    flat, tree = jax.tree.flatten(params)
    hist = [jnp.stack([f] * (S + 1)) for f in flat]     # ring of last S+1 params
    mom = [jnp.zeros_like(f) for f in flat]

    def step(carry, batch):
        hist, mom, t = carry
        # oldest params in the ring = W_{t-S} (clamped during cold history)
        idx = jnp.where(t >= S, (t - S) % (S + 1), 0)
        stale = tree.unflatten([h[idx] for h in hist])
        cur = tree.unflatten([h[t % (S + 1)] for h in hist])
        loss, grads = jax.value_and_grad(loss_fn)(stale, batch)
        gflat = jax.tree.leaves(grads)
        new_flat, new_mom = [], []
        for c, g, v in zip(jax.tree.leaves(cur), gflat, mom):
            if weight_decay:
                g = g + weight_decay * c
            v_new = momentum * v - lr * g
            new_flat.append(c + v_new)
            new_mom.append(v_new)
        new_hist = [h.at[(t + 1) % (S + 1)].set(nf)
                    for h, nf in zip(hist, new_flat)]
        out = (tree.unflatten(new_flat) if record_params else None, loss)
        return (new_hist, new_mom, t + 1), out

    (hist, mom, t), (trace, losses) = jax.lax.scan(
        step, (hist, mom, jnp.int32(0)), batches)
    final = tree.unflatten([h[t % (S + 1)] for h in hist])
    return final, losses, trace


# ---------------------------------------------------------------------------
# 2. Deployable SPMD grouped step
# ---------------------------------------------------------------------------

def scan_grouped_update(params, grads, mom_buf, *, lr: float, momentum: float,
                        weight_decay: float = 0.0, head_mask=None,
                        group_weights: Optional[Sequence[float]] = None):
    """Reference O(g) update application: the literal sequential scan over
    the g sub-steps (plus the merged-FC head update). ``grads`` carries a
    leading (g, ...) group axis per leaf. Returns (params, mom_buf).
    Argument order matches ``sgd_update`` and ``fused_group_update`` so the
    strategies are drop-in interchangeable.

    ``group_weights`` (unequal batch shares, ``cluster.allocator``): group
    i's gradient is pre-scaled by ``g * w_i / sum(w)`` before every use, so
    the head sees the share-weighted average and sub-step i a share-scaled
    step. Uniform weights scale by exactly 1.0 — bitwise the unweighted
    path.

    Kept as the semantic oracle for the fused closed-form path — it pays
    g read-modify-write passes over every leaf and a per-leaf fp32 cast
    round-trip per sub-step, which is exactly what fused_group_update
    collapses.
    """
    g = jax.tree.leaves(grads)[0].shape[0]
    if head_mask is None:
        head_mask = jax.tree.map(lambda _: False, params)
    scales = _weight_scales(g, group_weights)
    if scales is not None:
        sarr = jnp.asarray(scales, jnp.float32)
        grads = jax.tree.map(
            lambda gr: gr * sarr.reshape((g,) + (1,) * (gr.ndim - 1)).astype(
                gr.dtype), grads)

    # g == 1 deliberately takes the same one-iteration lax.scan path below
    # instead of shortcutting to sgd_update: one code path for every g, so
    # the engine's spmd/reference conformance suite exercises exactly what
    # g>1 runs (the shortcut compiled its weight-decay arithmetic with a
    # different FMA contraction than the scan body in some surrounding
    # programs — a one-ulp context dependence the single path avoids at
    # the suite's weight_decay=0 operating point; see docs/engine.md).

    # merged-FC head: single synchronous (share-weighted) averaged update
    # per round — with pre-scaled gradients the plain mean is that average
    head_grads = jax.tree.map(lambda gr: gr.mean(axis=0), grads)

    def upd_leaf(p, gg, v):
        g32 = gg.astype(jnp.float32)
        if weight_decay:
            g32 = g32 + weight_decay * p.astype(jnp.float32)
        v_new = momentum * v.astype(jnp.float32) - lr * g32
        return ((p.astype(jnp.float32) + v_new).astype(p.dtype),
                v_new.astype(v.dtype))

    def apply_one(carry, i):
        p, v = carry
        gi = jax.tree.map(lambda gr: gr[i], grads)
        # backbone: apply group-i gradient; head: untouched this sub-step
        new = jax.tree.map(
            lambda m, pp, gg, vv: (pp, vv) if m else upd_leaf(pp, gg, vv),
            head_mask, p, gi, v)
        p = jax.tree.map(lambda t: t[0], new,
                         is_leaf=lambda t: isinstance(t, tuple))
        v = jax.tree.map(lambda t: t[1], new,
                         is_leaf=lambda t: isinstance(t, tuple))
        return (p, v), None

    (params, mom_buf), _ = jax.lax.scan(
        apply_one, (params, mom_buf), jnp.arange(g))
    # head update (zero-staleness, merged FC), once per round
    new = jax.tree.map(
        lambda m, pp, gg, vv: upd_leaf(pp, gg, vv) if m else (pp, vv),
        head_mask, params, head_grads, mom_buf)
    params = jax.tree.map(lambda t: t[0], new,
                          is_leaf=lambda t: isinstance(t, tuple))
    mom_buf = jax.tree.map(lambda t: t[1], new,
                           is_leaf=lambda t: isinstance(t, tuple))
    return params, mom_buf


def apply_grouped_update(params, grads, mom_buf, *, strategy: str, lr: float,
                         momentum: float, weight_decay: float = 0.0,
                         head_mask=None,
                         group_weights: Optional[Sequence[float]] = None,
                         update_impl: str = "xla",
                         interpret: Optional[bool] = None,
                         coeffs=None, hcoeffs=None):
    """Apply one round of grouped updates (``grads`` leading axis = g) via
    either strategy — the single update-application entry point shared by
    ``make_grouped_train_step`` and the execution engine
    (``repro.engine``). Returns ``(params, mom_buf)``.

    ``coeffs`` / ``hcoeffs`` (``optim.closed_form``) may be precomputed by
    the caller for the fused path; when omitted they are derived here from
    (g, lr, momentum, weight_decay, group_weights).
    """
    if strategy == "scan":
        return scan_grouped_update(
            params, grads, mom_buf, lr=lr, momentum=momentum,
            weight_decay=weight_decay, head_mask=head_mask,
            group_weights=group_weights)
    if strategy != "fused":
        raise ValueError(f"unknown strategy {strategy!r}")
    g = jax.tree.leaves(grads)[0].shape[0]
    if coeffs is None:
        coeffs = grouped_coeffs(g, lr=lr, momentum=momentum,
                                weight_decay=weight_decay,
                                group_weights=group_weights)
    if hcoeffs is None:
        hcoeffs = head_coeffs(g, lr=lr, momentum=momentum,
                              weight_decay=weight_decay,
                              group_weights=group_weights)
    return fused_group_update(params, grads, mom_buf, coeffs=coeffs,
                              head_coeffs=hcoeffs, head_mask=head_mask,
                              impl=update_impl, interpret=interpret)


def head_mask_tree(params, head_filter: Optional[Callable]):
    """Python-bool tree marking merged-FC head leaves (True) — the mask
    consumed by both update strategies."""
    if head_filter is None:
        return jax.tree.map(lambda _: False, params)
    return jax.tree_util.tree_map_with_path(
        lambda path, _: bool(head_filter(path)), params)


def make_grouped_train_step(loss_fn: Callable, *, num_groups: int, lr: float,
                            momentum: float, weight_decay: float = 0.0,
                            head_filter: Optional[Callable] = None,
                            grad_accum: int = 1, strategy: str = "fused",
                            update_impl: str = "xla",
                            interpret: Optional[bool] = None,
                            group_weights: Optional[Sequence[float]] = None):
    """Build ``step(params, mom_buf, batches) -> (params, mom_buf, loss)``.

    ``batches``: pytree with leading axis ``(g, ...)`` (one microbatch per
    group, see ``group_batch_split``); with grad_accum > 1 the per-group
    batch has a further leading accumulation axis ``(g, A, ...)``.

    ``head_filter(path) -> bool`` marks head ("FC-phase") params: merged-FC
    semantics — their g per-group gradients are averaged and applied once
    per round (zero staleness), while backbone params receive the g updates
    with staleness 0..g-1.

    ``strategy``: "fused" (default) applies the closed form of the g
    sub-steps in one fused pass; "scan" is the literal sequential
    reference. ``update_impl``: "xla" or "pallas" leaf kernel for the
    fused path; ``interpret`` forces the Pallas interpreter (default:
    compile natively on TPU, interpret elsewhere).

    ``group_weights``: per-group batch shares from a heterogeneous
    allocation (``cluster.allocator.Allocation.weights``). Gradients are
    weighted ``g * w_i / sum(w)`` per sub-step and ``w_i / sum(w)`` in the
    merged-FC head average; uniform weights reproduce the equal-share path
    exactly.
    """
    if strategy not in ("fused", "scan"):
        raise ValueError(f"unknown strategy {strategy!r}")
    if group_weights is not None:
        group_weights = tuple(float(w) for w in group_weights)
    # interpret=None flows through to the leaf dispatch, which resolves it
    # (compile natively on TPU, interpret elsewhere) in one place
    g = num_groups
    coeffs = grouped_coeffs(g, lr=lr, momentum=momentum,
                            weight_decay=weight_decay,
                            group_weights=group_weights)
    hcoeffs = head_coeffs(g, lr=lr, momentum=momentum,
                          weight_decay=weight_decay,
                          group_weights=group_weights)

    def per_group_grad(params, batch):
        if grad_accum == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def acc_step(carry, micro):
            l, gr = jax.value_and_grad(loss_fn)(params, micro)
            return (carry[0] + l, jax.tree.map(jnp.add, carry[1], gr)), None
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (l, gr), _ = jax.lax.scan(acc_step, (jnp.float32(0.0), zeros), batch)
        return l / grad_accum, jax.tree.map(lambda x: x / grad_accum, gr)

    def step(params, mom_buf, batches):
        # all group gradients at round-start params, in parallel
        losses, grads = jax.vmap(per_group_grad, in_axes=(None, 0))(params, batches)
        params, mom_buf = apply_grouped_update(
            params, grads, mom_buf, strategy=strategy, lr=lr,
            momentum=momentum, weight_decay=weight_decay,
            head_mask=head_mask_tree(params, head_filter),
            group_weights=group_weights, update_impl=update_impl,
            interpret=interpret, coeffs=coeffs, hcoeffs=hcoeffs)
        return params, mom_buf, losses.mean()

    return step
