"""Small trainable workloads + a Runner factory for the optimizer
experiments (CPU-scale stand-ins for the paper's MNIST/CIFAR/ImageNet-8).

- ``quadratic``: noisy strongly-convex quadratic — Theorem 1 is exact here.
- ``mlp_classify``: 2-layer MLP on a synthetic Gaussian-cluster task.
- ``cnn_classify``: the paper's CNN family (LeNet-ish) on synthetic images,
  with the conv/FC phase split (merged-FC head_filter applies).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import cnn as cnn_mod


@dataclasses.dataclass
class Workload:
    name: str
    init: Callable                      # key -> params
    loss_fn: Callable                   # (params, batch) -> scalar
    sample_batches: Callable            # (key, steps, batch_size) -> stacked batches
    batch_size: int = 32
    head_filter: Optional[Callable] = None


def quadratic(dim: int = 32, cond: float = 10.0, noise: float = 0.1) -> Workload:
    eig = jnp.linspace(1.0, cond, dim) / cond
    def init(key):
        return {"w": jax.random.normal(key, (dim,))}
    def loss_fn(params, batch):
        g_noise = batch["xi"]
        w = params["w"]
        return 0.5 * jnp.sum(eig * w * w) + jnp.dot(g_noise, w)
    def sample(key, steps, batch_size):
        return {"xi": noise * jax.random.normal(key, (steps, dim))}
    return Workload("quadratic", init, loss_fn, sample, batch_size=1)


def mlp_classify(dim: int = 16, classes: int = 4, hidden: int = 32,
                 batch_size: int = 32) -> Workload:
    centers = jax.random.normal(jax.random.PRNGKey(99), (classes, dim)) * 2.0
    def init(key):
        k1, k2 = jax.random.split(key)
        return {"w1": jax.random.normal(k1, (dim, hidden)) * dim ** -0.5,
                "b1": jnp.zeros((hidden,)),
                "w2": jax.random.normal(k2, (hidden, classes)) * hidden ** -0.5,
                "b2": jnp.zeros((classes,))}
    def loss_fn(params, batch):
        h = jax.nn.relu(batch["x"] @ params["w1"] + params["b1"])
        logits = h @ params["w2"] + params["b2"]
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1).mean()
    def sample(key, steps, batch_size_):
        k1, k2 = jax.random.split(key)
        y = jax.random.randint(k1, (steps, batch_size_), 0, classes)
        x = centers[y] + jax.random.normal(k2, (steps, batch_size_, dim))
        return {"x": x, "y": y}
    return Workload("mlp", init, loss_fn, sample, batch_size=batch_size)


def cnn_classify(batch_size: int = 16) -> Workload:
    cfg = dataclasses.replace(cnn_mod.LENET, image_size=12, num_classes=4,
                              convs=(cnn_mod.ConvSpec(8, 3, pool=2),),
                              fc_dims=(16,))
    proto = jax.random.normal(jax.random.PRNGKey(5),
                              (4, cfg.image_size, cfg.image_size, 1))
    def init(key):
        return cnn_mod.init_params(key, cfg)
    def loss_fn(params, batch):
        return cnn_mod.loss_fn(params, batch, cfg)
    def sample(key, steps, bsz):
        k1, k2 = jax.random.split(key)
        y = jax.random.randint(k1, (steps, bsz), 0, 4)
        x = proto[y] + 0.5 * jax.random.normal(
            k2, (steps, bsz, cfg.image_size, cfg.image_size, 1))
        return {"images": x, "labels": y}
    return Workload("cnn", init, loss_fn, sample, batch_size=batch_size,
                    head_filter=cnn_mod.head_filter)


def rnn_classify(dim: int = 8, hidden: int = 24, seq: int = 16,
                 classes: int = 2, batch_size: int = 16) -> Workload:
    """Paper App. F-F (Fig. 32): the compute-group tradeoff on RNN/LSTM
    models. Single-layer LSTM over synthetic AR(1) sequences whose decay
    rate determines the class."""
    decays = jnp.linspace(0.35, 0.9, classes)

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "wx": jax.random.normal(k1, (dim, 4 * hidden)) * dim ** -0.5,
            "wh": jax.random.normal(k2, (hidden, 4 * hidden)) * hidden ** -0.5,
            "b": jnp.zeros((4 * hidden,)),
            "w_out": jax.random.normal(k3, (hidden, classes)) * hidden ** -0.5,
        }

    def lstm(params, xs):
        def cell(carry, x):
            h, c = carry
            z = x @ params["wx"] + h @ params["wh"] + params["b"]
            i, f, g, o = jnp.split(z, 4, axis=-1)
            c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            return (h, c), None
        b = xs.shape[0]
        h0 = (jnp.zeros((b, hidden)), jnp.zeros((b, hidden)))
        (h, _), _ = jax.lax.scan(cell, h0, xs.transpose(1, 0, 2))
        return h @ params["w_out"]

    def loss_fn(params, batch):
        logits = lstm(params, batch["x"])
        logp = jax.nn.log_softmax(logits)
        return -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1).mean()

    def sample(key, steps, bsz):
        k1, k2 = jax.random.split(key)
        y = jax.random.randint(k1, (steps, bsz), 0, classes)
        noise = jax.random.normal(k2, (steps, bsz, seq, dim))

        def roll(carry, n):
            d = carry[1]
            nxt = carry[0] * d[..., None] + n
            return (nxt, d), nxt
        d = decays[y]
        _, xs = jax.lax.scan(
            roll, (jnp.zeros((steps, bsz, dim)), d),
            noise.transpose(2, 0, 1, 3))
        return {"x": xs.transpose(1, 2, 0, 3), "y": y}

    return Workload("lstm", init, loss_fn, sample, batch_size=batch_size)


def make_runner(workload: Workload, *, seed: int = 0,
                weight_decay: float = 0.0, strategy: str = "delayed"):
    """Runner for Algorithm 1: an ``Engine`` configured from the workload
    (the engine *is* the Runner — ``repro.engine``). The default
    ``strategy="delayed"`` keeps the historical semantics: exact delayed
    SGD at staleness g-1, state = (params, step_counter), probe runs
    restarting from the same checkpoint without mutating the stream key
    schedule (paper App E). ``strategy="grouped-fused"``/``"grouped-scan"``
    run the same protocol on the deployable (mesh-sharded where devices
    allow) grouped step instead."""
    from repro.engine import Engine   # deferred: engine imports this module's
    #                                   sibling async_sgd, not workload itself
    return Engine(workload.loss_fn, strategy=strategy,
                  weight_decay=weight_decay, head_filter=workload.head_filter,
                  sample_batches=workload.sample_batches,
                  batch_size=workload.batch_size, seed=seed)


def init_state(workload: Workload, seed: int = 0):
    return (workload.init(jax.random.PRNGKey(seed)), 0)
