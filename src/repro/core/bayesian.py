"""Lightweight GP-EI Bayesian optimizer over (log-eta, mu, log2-g) — the
Snoek-style baseline the paper compares against (§VI-C2, Fig. 34).
NumPy-only (RBF kernel GP + expected improvement on a candidate grid)."""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Sequence, Tuple

import numpy as np


def _rbf(a: np.ndarray, b: np.ndarray, ls: np.ndarray) -> np.ndarray:
    d = (a[:, None, :] - b[None, :, :]) / ls
    return np.exp(-0.5 * np.sum(d * d, axis=-1))


@dataclasses.dataclass
class BayesResult:
    best_x: Tuple[float, float, int]     # (eta, mu, g)
    best_y: float
    history: List[Tuple[Tuple[float, float, int], float]]
    evaluations: int


def _encode(eta, mu, g):
    return np.array([np.log10(eta), mu, np.log2(g)])


def gp_ei_minimize(objective: Callable[[float, float, int], float],
                   *, etas: Sequence[float], mus: Sequence[float],
                   gs: Sequence[int], budget: int, seed: int = 0,
                   noise: float = 1e-6) -> BayesResult:
    """Minimize objective(eta, mu, g) with GP-EI over the finite grid."""
    rng = np.random.default_rng(seed)
    grid = [(e, m, g) for e in etas for m in mus for g in gs]
    X_all = np.stack([_encode(*p) for p in grid])
    ls = np.maximum(X_all.std(axis=0), 1e-3)

    history: List[Tuple[Tuple[float, float, int], float]] = []
    # 3 random warmup points
    idx0 = rng.choice(len(grid), size=min(3, budget), replace=False)
    for i in idx0:
        y = float(objective(*grid[i]))
        history.append((grid[i], y))

    while len(history) < budget:
        Xo = np.stack([_encode(*h[0]) for h in history])
        yo = np.array([h[1] for h in history])
        finite = np.isfinite(yo)
        ycap = yo.copy()
        ycap[~finite] = (yo[finite].max() if finite.any() else 1e3) * 2
        mean, std = ycap.mean(), max(ycap.std(), 1e-6)
        yn = (ycap - mean) / std
        K = _rbf(Xo, Xo, ls) + noise * np.eye(len(Xo))
        Kinv = np.linalg.inv(K)
        Ks = _rbf(X_all, Xo, ls)
        mu_pred = Ks @ Kinv @ yn
        var = np.maximum(1.0 - np.einsum("ij,jk,ik->i", Ks, Kinv, Ks), 1e-9)
        sd = np.sqrt(var)
        best = yn.min()
        z = (best - mu_pred) / sd
        # EI with standard normal cdf/pdf
        import math
        cdf = 0.5 * (1 + np.vectorize(math.erf)(z / np.sqrt(2)))
        pdf = np.exp(-0.5 * z * z) / np.sqrt(2 * np.pi)
        ei = sd * (z * cdf + pdf)
        # never re-evaluate
        seen = {h[0] for h in history}
        order = np.argsort(-ei)
        nxt = next(i for i in order if grid[i] not in seen)
        y = float(objective(*grid[nxt]))
        history.append((grid[nxt], y))

    finite_hist = [(x, y) for x, y in history if np.isfinite(y)]
    bx, by = min(finite_hist, key=lambda h: h[1])
    return BayesResult(best_x=bx, best_y=by, history=history,
                       evaluations=len(history))
