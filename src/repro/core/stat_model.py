"""Statistical-efficiency (SE) bookkeeping — paper §IV-C / App F-C.

    SE(g)      = iterations to reach a target loss with g groups
    P_SE(S)    = SE(S) / SE(0)
    P_HE(S)    = HE(S) / HE(0)
    P_total(S) = P_SE * P_HE          (time-to-accuracy, normalized to sync)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Mapping, Optional, Sequence

import numpy as np

from repro.core.implicit_momentum import implicit_momentum


def iterations_to_loss(losses: Sequence[float], target: float,
                       smooth: int = 5) -> Optional[int]:
    """First iteration at which the running-mean loss reaches ``target``."""
    arr = np.asarray(losses, dtype=np.float64)
    if arr.size == 0:
        return None
    if smooth > 1:
        kernel = np.ones(min(smooth, arr.size)) / min(smooth, arr.size)
        arr = np.convolve(arr, kernel, mode="valid")
    hits = np.nonzero(arr <= target)[0]
    return int(hits[0]) if hits.size else None


@dataclasses.dataclass
class TradeoffPoint:
    g: int
    mu: float
    eta: float
    he_time: float                 # seconds / iteration (model or measured)
    se_iters: Optional[int]        # iterations to target loss

    @property
    def total_time(self) -> Optional[float]:
        if self.se_iters is None:
            return None
        return self.he_time * self.se_iters


def penalty_ratio(value, baseline) -> Optional[float]:
    """Normalized penalty with explicit degenerate-case semantics.

    ``None``     — unknown: either side never reached the target
                   (``se_iters is None``).
    ``math.inf`` — the sync baseline hit the target instantly (0
                   iterations) but this point didn't: infinitely worse.
    ``1.0``      — both sides are 0: equally instant.

    (A plain truthiness test, as previously used, silently collapsed a
    legitimate 0 to "unknown" and a 0 baseline to a ZeroDivisionError.)
    """
    if value is None or baseline is None:
        return None
    if baseline == 0:
        return math.inf if value > 0 else 1.0
    return value / baseline


def penalties(points: Dict[int, TradeoffPoint]):
    """Normalize a {g: point} sweep to the sync point (paper's P_* curves).

    Requires the sync (g=1) baseline; missing/zero SE data degrades to the
    explicit ``None``/``math.inf`` semantics of ``penalty_ratio``.
    """
    if 1 not in points:
        raise ValueError("penalties() needs the sync baseline (g=1 point)")
    base = points[1]
    out = {}
    for g, pt in sorted(points.items()):
        out[g] = {
            "P_HE": pt.he_time / base.he_time,
            "P_SE": penalty_ratio(pt.se_iters, base.se_iters),
            "P_total": penalty_ratio(pt.total_time, base.total_time),
            "implicit_momentum": implicit_momentum(g),
            "mu": pt.mu, "eta": pt.eta,
        }
    return out


def measured_se_from_replay(replay_losses: Mapping[int, Sequence[float]],
                            target: float, *, smooth: int = 5
                            ) -> Dict[int, Dict[str, Optional[float]]]:
    """SE calibration from *executed* traces rather than the analytic
    penalty: ``replay_losses`` maps g -> the loss curve of an
    ``exec.replay`` run along a g-group event trace (e.g. from
    ``queue_sim.simulate(..., return_trace=True)``).

    Returns ``{g: {"se_iters", "P_SE"}}`` — iterations to ``target`` and
    the penalty normalized to the g=1 entry (``penalty_ratio`` semantics:
    ``None`` when either side never converged). The P_SE values plug
    straight into the planner (``cluster.planner.best_allocation(
    se_penalties=...)``), which is how Algorithm 1's initial-g choice can
    be calibrated from executions.

    Like ``penalties()``, requires the sync baseline — P_SE is
    meaningless without a g=1 curve to normalize against.
    """
    iters = {int(g): iterations_to_loss(l, target, smooth=smooth)
             for g, l in replay_losses.items()}
    if 1 not in iters:
        raise ValueError(
            "measured_se_from_replay() needs the sync baseline "
            "(a g=1 replayed loss curve)")
    base = iters[1]
    return {g: {"se_iters": n, "P_SE": penalty_ratio(n, base)}
            for g, n in sorted(iters.items())}


def predict_se_penalty(g: int, mu_star_total: float, sharpness: float = 4.0):
    """Qualitative SE-penalty model: no penalty while implicit momentum stays
    below the optimal total momentum, growing penalty beyond (Fig. 6/7)."""
    mu_i = implicit_momentum(g)
    if mu_i <= mu_star_total:
        return 1.0
    return float(1.0 + sharpness * (mu_i - mu_star_total) / (1 - mu_star_total))
