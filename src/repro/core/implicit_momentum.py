"""Theorem 1 — asynchrony begets momentum (paper §IV-C, [Mitliagkas 2016]).

With g asynchronous groups and explicit momentum 0, the expected update obeys
    E V_{t+1} = (1 - 1/g) E V_t - (eta/g) E grad(W_t)
i.e. implicit momentum mu_impl = 1 - 1/g.

``measure_effective_momentum`` estimates the momentum modulus from an
observed parameter trace by least squares on the update recursion — the
estimator behind the paper's Fig. 6 "measured momentum" panels.
"""
from __future__ import annotations

import numpy as np


def implicit_momentum(g: int) -> float:
    return 1.0 - 1.0 / g


def total_momentum(g: int, explicit_mu: float) -> float:
    """Composition used by the optimizer: momenta compose like moduli."""
    return 1.0 - (1.0 - implicit_momentum(g)) * (1.0 - explicit_mu)


def optimal_explicit_momentum(g: int, mu_star_total: float) -> float:
    """Explicit momentum that restores the sync-optimal total momentum;
    0 (and an SE penalty) once implicit momentum exceeds mu_star_total."""
    mu_i = implicit_momentum(g)
    if mu_i >= mu_star_total:
        return 0.0
    return 1.0 - (1.0 - mu_star_total) / (1.0 - mu_i)


def measure_effective_momentum(param_trace: np.ndarray,
                               grads_at_trace: np.ndarray,
                               lr: float, *, fit_lr: bool = False) -> float:
    """Fit mu in  dW_{t+1} = mu dW_t - eta_eff * grad_t  by least squares
    over a flattened parameter trace (T, D). Returns the fitted momentum
    modulus. ``grads_at_trace``: gradients evaluated at W_t (T, D).

    ``fit_lr=False`` assumes ``eta_eff == lr`` (one-parameter fit — right
    when the trace comes from explicit-momentum SGD at a known step size).
    ``fit_lr=True`` fits (mu, eta_eff) jointly and ignores ``lr`` — the
    estimator for *replayed* asynchronous traces, where Theorem 1 predicts
    eta_eff = lr/g alongside mu = 1 - 1/g (the paper's Fig. 6 measured
    momentum; trajectories from ``exec.replayed_momentum_experiment``)."""
    w = np.asarray(param_trace, dtype=np.float64)
    g = np.asarray(grads_at_trace, dtype=np.float64)
    dw = np.diff(w, axis=0)                        # (T-1, D)
    if dw.shape[0] < 3:
        raise ValueError("trace too short")
    if fit_lr:
        y = dw[1:].ravel()
        X = np.stack([dw[:-1].ravel(), g[1:-1].ravel()], axis=1)
        coef, *_ = np.linalg.lstsq(X, y, rcond=None)
        return float(coef[0])
    y = (dw[1:] + lr * g[1:-1]).ravel()            # target: mu * dW_t (+ lr-scale slack)
    x = dw[:-1].ravel()
    denom = float(x @ x)
    if denom == 0.0:
        return 0.0
    return float(x @ y) / denom


def async_quadratic_sim(*, g: int, eta: float, steps: int, runs: int = 200,
                        a: float = 1.0, seed: int = 0, w0: float = 1.0,
                        noise: float = 0.0) -> np.ndarray:
    """Simulate Theorem 1's exact model on a 1-D quadratic (loss = a w^2 / 2):
    g asynchronous workers with exponential (memoryless) service times — so
    each commit comes from a uniformly-random worker whose gradient was read
    at its own previous commit. Returns the run-averaged trajectory (steps+1,).

    The expected dynamics obey
        E w_{t+1} = E w_t + (1-1/g)(E w_t - E w_{t-1}) - (eta a / g) E w_t,
    i.e. an AR(2) with momentum coefficient exactly 1 - 1/g.
    """
    rng = np.random.default_rng(seed)
    traj = np.zeros((runs, steps + 1))
    for r in range(runs):
        w = w0
        read_w = np.full(g, w0)            # params each worker last read
        ws = [w]
        for t in range(steps):
            i = rng.integers(g)            # memoryless race -> uniform worker
            grad = a * read_w[i]
            if noise:
                grad += noise * rng.standard_normal()
            w = w - eta * grad
            read_w[i] = w                  # worker re-reads after commit
            ws.append(w)
        traj[r] = ws
    return traj.mean(axis=0)


def fit_ar2_momentum(traj: np.ndarray):
    """Fit the heavy-ball recursion  V_{t+1} = mu V_t - eta_eff W_t  on an
    expected trajectory. Returns (mu, eta_eff) — Theorem 1 predicts
    (1 - 1/g, eta/g)."""
    w = np.asarray(traj, dtype=np.float64)
    v = np.diff(w)
    y = v[1:]
    X = np.stack([v[:-1], w[1:-1]], axis=1)
    coef, *_ = np.linalg.lstsq(X, y, rcond=None)
    return float(coef[0]), float(-coef[1])


def measure_momentum_from_updates(updates: np.ndarray) -> float:
    """Momentum modulus from successive updates alone (autocorrelation
    estimator): mu ≈ <dW_{t+1}, dW_t> / <dW_t, dW_t>, averaged over t.
    Valid near a quadratic minimum where the gradient term is small noise."""
    u = np.asarray(updates, dtype=np.float64)
    num = float(np.sum(u[1:] * u[:-1]))
    den = float(np.sum(u[:-1] * u[:-1]))
    return num / den if den else 0.0
