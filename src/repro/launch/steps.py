"""Step factories (train / prefill / decode) + ShapeDtypeStruct input specs
for the dry-run. Decode shapes lower ``decode_step`` (one token + cache),
train lowers a full SGD-momentum update, prefill lowers forward+cache.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape, TrainConfig
from repro.models import transformer as T
from repro.optim.sgd import sgd_update

# sliding window used for the long_500k sub-quadratic attention variant
LONG_CONTEXT_WINDOW = 8192


def effective_window(cfg: ArchConfig, shape: InputShape) -> Optional[int]:
    """long_500k on attention-bearing archs runs the sliding-window variant
    (sub-quadratic); other shapes use the config's native attention."""
    if shape.name == "long_500k" and cfg.arch_type in ("dense", "moe", "vlm"):
        return LONG_CONTEXT_WINDOW
    return cfg.sliding_window


def supports_shape(cfg: ArchConfig, shape: InputShape) -> bool:
    """whisper-base: enc-dec over <=30s audio has no 500k-token decode
    regime (the long_500k shape in ``configs.base.INPUT_SHAPES`` is a
    decode-regime shape; an encoder bounded to 30s of audio never sees
    it)."""
    if cfg.arch_type == "encdec" and shape.name == "long_500k":
        return False
    return True


def batch_specs(cfg: ArchConfig, shape: InputShape, *, grad_accum: int = 1):
    """ShapeDtypeStructs for the data inputs of the step (weak-type-correct,
    shardable, no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    if shape.kind == "train":
        if grad_accum > 1:
            assert B % grad_accum == 0
            b = B // grad_accum
            batch = {"tokens": sd((grad_accum, b, S), jnp.int32),
                     "labels": sd((grad_accum, b, S), jnp.int32)}
            lead = (grad_accum, b)
        else:
            batch = {"tokens": sd((B, S), jnp.int32),
                     "labels": sd((B, S), jnp.int32)}
            lead = (B,)
    elif shape.kind == "prefill":
        batch = {"tokens": sd((B, S), jnp.int32)}
        lead = (B,)
    else:  # decode: one new token
        batch = {"tokens": sd((B, 1), jnp.int32)}
        lead = (B,)
    # modality frontends are STUBS: precomputed embeddings of the right shape
    if cfg.arch_type == "encdec":
        batch["enc_emb"] = sd((*lead, cfg.encoder_seq, cfg.d_model),
                              cfg.dtype("compute"))
    if cfg.arch_type == "vlm":
        batch["img_emb"] = sd((*lead, cfg.num_image_tokens, cfg.d_model),
                              cfg.dtype("compute"))
    return batch


def params_specs(cfg: ArchConfig, seed: int = 0):
    return jax.eval_shape(
        lambda k: T.init_params(k, cfg), jax.random.PRNGKey(seed))


def cache_specs_struct(cfg: ArchConfig, shape: InputShape):
    window = effective_window(cfg, shape)
    return jax.eval_shape(
        functools.partial(T.init_cache, cfg, shape.global_batch,
                          shape.seq_len, window))


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(cfg: ArchConfig, tc: TrainConfig, shape: InputShape,
                    *, attn_impl: str = "xla", grad_shardings=None):
    """Synchronous (g=1) data-parallel SGD-momentum step with optional
    gradient-accumulation microbatching. ``grad_shardings`` (same tree as
    params) pins the accumulator layout — without it GSPMD replicates the
    fp32 accumulator per chip and all-reduces every microstep. For g>1 —
    and for the whole training loop (prefetch, telemetry, donation) — see
    the unified execution engine, ``repro.engine`` (docs/engine.md)."""
    window = effective_window(cfg, shape)

    def loss_fn(params, batch):
        return T.lm_loss(params, batch, cfg, attn_impl=attn_impl,
                         window=window)

    def _constrain(tree):
        if grad_shardings is None:
            return tree
        return jax.tree.map(jax.lax.with_sharding_constraint, tree,
                            grad_shardings)

    def train_step(params, mom, batch):
        if tc.grad_accum > 1:
            def acc(carry, micro):
                l, g = jax.value_and_grad(loss_fn)(params, micro)
                g = _constrain(g)
                return (carry[0] + l,
                        _constrain(jax.tree.map(jnp.add, carry[1], g))), None
            zeros = _constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (loss, grads), _ = jax.lax.scan(acc, (jnp.float32(0.0), zeros),
                                            batch)
            loss = loss / tc.grad_accum
            grads = jax.tree.map(lambda g: g / tc.grad_accum, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, mom = sgd_update(params, grads, mom, lr=tc.learning_rate,
                                 momentum=tc.momentum,
                                 weight_decay=tc.weight_decay)
        return params, mom, loss

    return train_step


def make_prefill_step(cfg: ArchConfig, shape: InputShape,
                      *, attn_impl: str = "xla"):
    window = effective_window(cfg, shape)

    def prefill_step(params, batch):
        logits, _, cache = T.forward(params, batch, cfg, return_cache=True,
                                     attn_impl=attn_impl, window=window)
        return logits[:, -1:, :], cache

    return prefill_step


def make_decode_step(cfg: ArchConfig, shape: InputShape):
    window = effective_window(cfg, shape)

    def decode_step(params, cache, batch, pos):
        logits, cache = T.decode_step(params, cache, batch["tokens"], pos,
                                      cfg, window=window)
        next_tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache

    return decode_step
