import os
import sys
# Forced host device pool, set before jax initializes its backend: 8 for the
# --host-smoke CI lane (matches the tier-1 test pool), 512 for production
# dry-runs. An externally provided XLA_FLAGS (test harness subprocess) wins.
os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_force_host_platform_device_count="
    + ("8" if "--host-smoke" in sys.argv[1:] else "512"))

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) combination with ShapeDtypeStruct
stand-ins — no allocation — and record memory analysis, cost analysis and
the collective schedule for the roofline (EXPERIMENTS.md §Dry-run/§Roofline).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod-only]
  PYTHONPATH=src python -m repro.launch.dryrun --host-smoke
Results: experiments/dryrun/<arch>__<shape>__<mesh>.json

--host-smoke is the CI regression lane for the big configs: it lowers AND
compiles the 405B-class architectures through the canonical
("group","data","mp") mesh on 8 forced host devices (no allocation —
AOT compile over ShapeDtypeStructs) and fails on HLO/memory-model
regressions: a compile error, params that stopped sharding over the mp
axis, a vanished collective schedule, or per-device argument bytes
blowing past the sharded-state memory model.
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from pathlib import Path  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import INPUT_SHAPES, get_config, list_archs  # noqa: E402
from repro.configs.base import TrainConfig                      # noqa: E402
from repro.launch import steps as ST                            # noqa: E402
from repro.launch.hlo_analysis import roofline_from_compiled  # noqa: E402
from repro.launch.mesh import make_production_mesh              # noqa: E402
from repro.launch.params_util import (active_param_count,       # noqa: E402
                                      param_bytes, param_count)
from repro.sharding import rules as SH                          # noqa: E402

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# grad-accum (microbatching) for train_4k, tuned so remat'd activations fit
# HBM; inference shapes never accumulate.
GRAD_ACCUM = {
    "llama3-405b": 16,
    "llama-3.2-vision-90b": 16,
    "grok-1-314b": 16,
    "deepseek-coder-33b": 8,
    "qwen2-7b": 8,
    "phi4-mini-3.8b": 8,
    "qwen2-moe-a2.7b": 8,
    "mamba2-2.7b": 8,
    "recurrentgemma-2b": 8,
    "whisper-base": 8,   # 51 GiB/chip of fp32 logit temporaries at accum=1
}


def _tokens_per_step(shape) -> float:
    if shape.kind == "train":
        return shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return shape.global_batch * shape.seq_len
    return shape.global_batch  # decode: 1 token per sequence


def lower_one(arch: str, shape_name: str, *, multi_pod: bool,
              tc: TrainConfig = None, verbose: bool = True,
              accum_override: int = None, seq_parallel: bool = False,
              weight_stationary: bool = False, tag: str = ""):
    """Returns a result dict (raises on lowering/compile failure)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if not ST.supports_shape(cfg, shape):
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped",
                "reason": "encdec has no 500k-token decode regime "
                          "(launch.steps.supports_shape)"}

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    accum = GRAD_ACCUM.get(arch, 1) if shape.kind == "train" else 1
    if accum_override is not None and shape.kind == "train":
        accum = accum_override
    if tc is None:
        tc = TrainConfig(grad_accum=accum)

    pspecs = ST.params_specs(cfg)
    p_shard = SH.params_shardings(pspecs, cfg, mesh,
                                  decode_kv_hd=weight_stationary
                                  and shape.kind == "decode")
    bspecs = ST.batch_specs(cfg, shape, grad_accum=tc.grad_accum)
    b_shard = SH.batch_shardings(bspecs, mesh,
                                 batch_dim=1 if tc.grad_accum > 1 else 0)
    t0 = time.time()

    act_ctx = SH.activation_sharding(mesh, seq_parallel_attention=seq_parallel,
                                     weight_stationary=weight_stationary)
    with mesh, act_ctx:
        if shape.kind == "train":
            mspecs = jax.eval_shape(
                lambda p: jax.tree.map(
                    lambda x: jnp.zeros(x.shape, cfg.dtype("mom")), p), pspecs)
            m_shard = SH.params_shardings(mspecs, cfg, mesh)
            step = ST.make_train_step(cfg, tc, shape, grad_shardings=p_shard)
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, m_shard, b_shard),
                out_shardings=(p_shard, m_shard, SH.replicated(mesh)),
            ).lower(pspecs, mspecs, bspecs)
        elif shape.kind == "prefill":
            step = ST.make_prefill_step(cfg, shape)
            lowered = jax.jit(
                step, in_shardings=(p_shard, b_shard),
            ).lower(pspecs, bspecs)
        else:  # decode
            cspecs = ST.cache_specs_struct(cfg, shape)
            c_shard = SH.cache_shardings(cspecs, cfg, mesh,
                                         batch=shape.global_batch)
            step = ST.make_decode_step(cfg, shape)
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, b_shard,
                              SH.replicated(mesh)),
                out_shardings=(SH.replicated(mesh), c_shard),
            ).lower(pspecs, cspecs, bspecs, jax.ShapeDtypeStruct((), jnp.int32))
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    ma = compiled.memory_analysis()
    n_total = param_count(pspecs)
    n_active = active_param_count(pspecs, cfg)
    pbytes = param_bytes(pspecs)
    cache_bytes = 0.0
    if shape.kind == "decode":
        cspecs2 = ST.cache_specs_struct(cfg, shape)
        cache_bytes = float(sum(
            jnp.zeros((), l.dtype).itemsize * float(jnp.prod(jnp.array(l.shape)))
            for l in jax.tree.leaves(cspecs2)))
    from repro.launch.hlo_analysis import analytic_hbm_bytes
    hbm = analytic_hbm_bytes(cfg, shape, chips, grad_accum=tc.grad_accum,
                             params_bytes_global=pbytes,
                             cache_bytes_global=cache_bytes)
    roof = roofline_from_compiled(compiled, chips, hbm_bytes=hbm)
    from repro.launch.hlo_parse import analyze_module
    stats = analyze_module(compiled.as_text())
    tokens = _tokens_per_step(shape)
    # 6ND for training (fwd+bwd), 2ND for inference (fwd only)
    model_flops = (6.0 if shape.kind == "train" else 2.0) * n_active * tokens

    res = {
        "arch": arch, "shape": shape_name, "variant": tag or "baseline",
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips, "status": "ok",
        "seq_parallel": seq_parallel,
        "grad_accum": tc.grad_accum,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "params_total": n_total, "params_active": n_active,
        "param_bytes_global": param_bytes(pspecs),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_per_chip_est": ma.argument_size_in_bytes
                                 + ma.temp_size_in_bytes,
        },
        "roofline": roof.as_dict(),
        "collectives": {"bytes": stats.collective_bytes,
                        "count": stats.collective_counts},
        "model_flops_global": model_flops,
        "useful_flops_frac": (model_flops / chips) / roof.flops
                             if roof.flops else None,
    }
    if verbose:
        print(f"[{res['mesh']}] {arch} x {shape_name}: "
              f"compile {res['compile_s']}s, "
              f"mem/chip {(res['memory']['peak_per_chip_est'])/2**30:.2f} GiB, "
              f"bottleneck {roof.bottleneck}, step {roof.step_time*1e3:.2f} ms")
    return res


# Big configs exercised by the CI host-smoke lane (dense 405B-class, MoE,
# SSM — one per memory-model family).
HOST_SMOKE_ARCHS = ("llama3-405b", "qwen2-moe-a2.7b", "mamba2-2.7b")


def host_smoke_one(arch: str, *, groups: int = 1, data: int = 4, mp: int = 2,
                   seq_len: int = 128, batch: int = 8, verbose: bool = True):
    """Lower + compile ``arch``'s full train step through the canonical
    ("group","data","mp") mesh on forced host devices, then check the
    memory model and HLO still behave. Returns a result dict; raises
    AssertionError / compile errors on regression.

    Checks (the "fails on HLO/memory-model regressions" contract):
      * lower + AOT compile succeed on the engine-canonical mesh;
      * when mp > 1, at least one param leaf is sharded over "mp";
      * compiled per-device argument bytes respect the sharded-state
        memory model: <= state_bytes / (data*mp) * 1.3 + 1 GiB slack
        (a replication regression inflates this by ~data*mp and trips);
      * the HLO still contains a collective schedule (sharded params on
        a multi-device mesh must communicate; zero collectives means the
        partitioner silently stopped sharding).
    """
    from repro.configs.base import InputShape
    from repro.launch.mesh import make_host_smoke_mesh

    n_dev = jax.device_count()
    need = groups * data * mp
    if need > n_dev:
        raise ValueError(f"host-smoke mesh {groups}x{data}x{mp} needs {need} "
                         f"devices, have {n_dev}")
    cfg = get_config(arch)
    shape = InputShape("hostsmoke", seq_len, batch, "train")
    mesh = make_host_smoke_mesh(data=data, mp=mp, groups=groups)
    tc = TrainConfig(grad_accum=1)

    pspecs = ST.params_specs(cfg)
    p_shard = SH.params_shardings(pspecs, cfg, mesh)
    bspecs = ST.batch_specs(cfg, shape, grad_accum=1)
    b_shard = SH.batch_shardings(bspecs, mesh)
    t0 = time.time()
    with mesh, SH.activation_sharding(mesh):
        mspecs = jax.eval_shape(
            lambda p: jax.tree.map(
                lambda x: jnp.zeros(x.shape, cfg.dtype("mom")), p), pspecs)
        m_shard = SH.params_shardings(mspecs, cfg, mesh)
        step = ST.make_train_step(cfg, tc, shape, grad_shardings=p_shard)
        lowered = jax.jit(
            step,
            in_shardings=(p_shard, m_shard, b_shard),
            out_shardings=(p_shard, m_shard, SH.replicated(mesh)),
        ).lower(pspecs, mspecs, bspecs)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    # --- HLO sanity: the mp axis must actually shard parameter storage ---
    def _axes(spec):
        out = set()
        for entry in tuple(spec):
            for a in (entry if isinstance(entry, tuple) else (entry,)):
                if a is not None:
                    out.add(a)
        return out

    shardings = jax.tree.leaves(
        p_shard, is_leaf=lambda x: hasattr(x, "spec"))
    mp_leaves = sum(1 for s in shardings if "mp" in _axes(s.spec))
    if mp > 1:
        assert mp_leaves > 0, (
            f"{arch}: no param leaf is sharded over the 'mp' axis — the "
            "mesh/rules unification regressed (rules.default_axes)")

    # --- memory model: arguments must be state-sharded, not replicated ---
    ma = compiled.memory_analysis()
    pbytes = param_bytes(pspecs)
    import math
    mom_bytes = float(sum(
        jnp.zeros((), l.dtype).itemsize * math.prod(l.shape)
        for l in jax.tree.leaves(mspecs)))
    state_bytes = pbytes + mom_bytes
    arg_bound = state_bytes / (data * mp) * 1.3 + 2.0**30
    assert ma.argument_size_in_bytes <= arg_bound, (
        f"{arch}: per-device argument bytes "
        f"{ma.argument_size_in_bytes/2**30:.1f} GiB exceed the sharded-state "
        f"model bound {arg_bound/2**30:.1f} GiB "
        f"(state {state_bytes/2**30:.1f} GiB over data*mp={data*mp}) — "
        "parameters or momentum replicated?")

    from repro.launch.hlo_parse import analyze_module
    stats = analyze_module(compiled.as_text())
    n_coll = int(sum(stats.collective_counts.values()))
    if need > 1:
        assert n_coll > 0, (
            f"{arch}: compiled HLO has no collectives on a {need}-device "
            "mesh — partitioner silently stopped sharding")

    res = {
        "arch": arch, "shape": "hostsmoke", "status": "ok",
        "mesh": f"{groups}x{data}x{mp}", "mesh_axes": list(mesh.axis_names),
        "chips": need, "seq_len": seq_len, "global_batch": batch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "params_total": param_count(pspecs),
        "state_bytes_global": state_bytes,
        "mp_sharded_param_leaves": mp_leaves,
        "param_leaves": len(shardings),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "argument_bound_bytes": arg_bound,
        },
        "collectives": {"bytes": stats.collective_bytes,
                        "count": stats.collective_counts},
    }
    if verbose:
        print(f"[host-smoke {res['mesh']}] {arch}: "
              f"lower {res['lower_s']}s, compile {res['compile_s']}s, "
              f"args/dev {ma.argument_size_in_bytes/2**30:.1f} GiB "
              f"(bound {arg_bound/2**30:.1f}), "
              f"mp-sharded leaves {mp_leaves}/{len(shardings)}, "
              f"collectives {n_coll}")
    return res


def run_host_smoke(args):
    """CLI driver for --host-smoke: run every HOST_SMOKE_ARCHS config (or
    just --arch), write JSON next to the production dry-run artifacts,
    exit non-zero on any regression."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    archs = [args.arch] if args.arch else list(HOST_SMOKE_ARCHS)
    failures = []
    for arch in archs:
        tag = f"{arch}__hostsmoke__{args.smoke_g}x{args.smoke_data}x{args.smoke_mp}"
        try:
            res = host_smoke_one(arch, groups=args.smoke_g,
                                 data=args.smoke_data, mp=args.smoke_mp)
        except Exception as e:
            traceback.print_exc()
            res = {"arch": arch, "shape": "hostsmoke", "status": "FAILED",
                   "mesh": f"{args.smoke_g}x{args.smoke_data}x{args.smoke_mp}",
                   "error": str(e)[-2000:]}
            failures.append(tag)
        (OUT_DIR / f"{tag}.json").write_text(json.dumps(res, indent=2))
    if failures:
        print("HOST-SMOKE FAILURES:", failures)
        raise SystemExit(1)
    print(f"host-smoke OK ({len(archs)} configs)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs())
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true",
                    help="run the 2x16x16 mesh (default 16x16)")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--accum", type=int, default=None,
                    help="override grad accumulation (hillclimb variant)")
    ap.add_argument("--seqpar", action="store_true",
                    help="sequence-parallel attention variant")
    ap.add_argument("--wstat", action="store_true",
                    help="weight-stationary decode variant")
    ap.add_argument("--tag", type=str, default="",
                    help="variant tag appended to the output filename")
    ap.add_argument("--host-smoke", action="store_true",
                    help="CI lane: compile the big configs on a forced "
                         "8-host-device ('group','data','mp') mesh and fail "
                         "on HLO/memory-model regressions")
    ap.add_argument("--smoke-g", type=int, default=1,
                    help="host-smoke mesh: compute groups")
    ap.add_argument("--smoke-data", type=int, default=4,
                    help="host-smoke mesh: data-parallel width")
    ap.add_argument("--smoke-mp", type=int, default=2,
                    help="host-smoke mesh: model-parallel width")
    args = ap.parse_args()

    if args.host_smoke:
        run_host_smoke(args)
        return

    OUT_DIR.mkdir(parents=True, exist_ok=True)
    archs = list_archs() if args.all or not args.arch else [args.arch]
    shapes = sorted(INPUT_SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}"
                if args.tag:
                    tag += f"__{args.tag}"
                try:
                    res = lower_one(arch, shape, multi_pod=mp,
                                    accum_override=args.accum,
                                    seq_parallel=args.seqpar,
                                    weight_stationary=args.wstat,
                                    tag=args.tag)
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    res = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "FAILED", "error": str(e)[-2000:]}
                    failures.append(tag)
                (OUT_DIR / f"{tag}.json").write_text(json.dumps(res, indent=2))
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("all dry-runs OK")


if __name__ == "__main__":
    main()
