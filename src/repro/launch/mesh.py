"""Production meshes. Functions (not module constants) so importing never
touches jax device state.

Axis naming is unified on the engine's ("group", "data", "mp") canon:

  group  async compute groups (paper §IV-A round-robin staleness axis)
  data   synchronous data parallelism within a group
  mp     model parallelism within a worker (param/optimizer-state shards)

``sharding.rules`` reads the tensor/fsdp axis names *from the mesh*
(``rules.default_axes``), so the legacy production/dryrun meshes — which
keep their historical ("pod", "data", "model") naming as a compat shim for
the recorded dry-run artifacts — and the engine's group mesh consume the
same rule code.
"""
from __future__ import annotations

import jax

#: canonical engine mesh axes (model-parallel axis last)
GROUP_MESH_AXES = ("group", "data", "mp")


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16,16)=256 chips, ("data","model").
    Multi-pod: (2,16,16)=512 chips, ("pod","data","model").

    Compat shim: these keep the historical axis names the recorded dry-run
    artifacts were produced with; ``sharding.rules`` resolves axis roles
    from the mesh, so the naming difference is invisible to rule code.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires >= data*model host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_host_smoke_mesh(data: int = 4, mp: int = 2, groups: int = 1):
    """Forced-host-device mesh in the canonical ("group","data","mp")
    naming for the dryrun-smoke lane: ``groups`` compute groups of
    ``data`` fsdp-style shards times ``mp`` model shards (requires
    >= groups*data*mp host devices). Param rules replicate over "group"
    (``sharding.rules.default_axes``), mirroring the engine."""
    return jax.make_mesh((groups, data, mp), GROUP_MESH_AXES)


def make_group_mesh(groups: int, data: int = 1, mp: int = 1):
    """Compute-group mesh for the execution engine: (g, k, mp) devices
    with axes ("group", "data", "mp") — g async compute groups of k
    synchronous data-parallel workers, each worker ``mp`` model-parallel
    devices holding one shard of the parameters and optimizer state
    (paper §IV-A for the group axis; the mp axis is the within-worker
    partitioning the planner's 2-D (g, mp) search allocates). Uses the
    first g*k*mp local devices, so it works on any prefix of the host/TPU
    device pool (CPU-testable via --xla_force_host_platform_device_count).
    """
    from jax.sharding import Mesh

    from repro.engine.spmd import group_mesh_devices
    return Mesh(group_mesh_devices(groups, data, mp), GROUP_MESH_AXES)
