"""Production meshes. Functions (not module constants) so importing never
touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16,16)=256 chips, ("data","model").
    Multi-pod: (2,16,16)=512 chips, ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2):
    """Small mesh for CPU tests (requires >= data*model host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_group_mesh(groups: int, data: int = 1):
    """Compute-group mesh for the execution engine: (g, k) devices with
    axes ("group", "data") — g async compute groups of k synchronous
    data-parallel devices each (paper §IV-A). Uses the first g*k local
    devices, so it works on any prefix of the host/TPU device pool
    (CPU-testable via --xla_force_host_platform_device_count).
    """
    from jax.sharding import Mesh

    from repro.engine.spmd import group_mesh_devices
    return Mesh(group_mesh_devices(groups, data), ("group", "data"))
