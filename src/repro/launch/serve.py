"""Serving driver: static batched generation or trace-driven continuous
batching (``repro.serving``).

Two modes:

- ``--mode static`` (the original flow, kept as the baseline): one batch,
  single-pass jitted prefill filling the whole KV cache, then a per-token
  decode loop. Per-phase timings go through the ``obs`` metric registry
  on the repo's one monotonic clock.
- ``--mode continuous``: a Poisson request trace (``--rate``/``--requests``,
  or ``--arrival-trace`` to replay a saved ``EventTrace``) served by the
  ``ContinuousServer`` — slot-recycled paged KV cache, one compiled decode
  step for a changing request population, bucketed prefill — reported as
  tok/s + p50/p99 latency + goodput at ``--slo-ms``, with the static
  baseline on the same trace for comparison.

CPU-runnable:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-7b --smoke \
      --mode continuous --rate 40 --requests 24 --slo-ms 500
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.engine.timing import monotonic
from repro.models import transformer as T
from repro.obs import spans
from repro.obs.metrics import MetricRegistry


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0,
          registry: MetricRegistry | None = None):
    """Static-batch generation: one jitted prefill call fills the whole
    KV cache, then a per-token decode loop for the generated suffix.
    Phase timings land in ``registry`` (series ``serve.prefill_s`` /
    ``serve.decode_s``). Returns (gen_tokens, prefill_seconds,
    decode_seconds)."""
    reg = registry if registry is not None else MetricRegistry()
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(cfg.vocab_size,
                                       size=(batch, prompt_len)), jnp.int32)
    total = prompt_len + gen
    cache = T.init_cache(cfg, batch, total)

    prefill = jax.jit(lambda p, c, toks: T.prefill(p, c, toks, cfg))
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))

    t0 = monotonic()
    with spans.span("serve.prefill", batch=batch, prompt_len=prompt_len):
        logits, cache = jax.block_until_ready(prefill(params, cache, prompts))
    t_prefill = monotonic() - t0
    reg.series("serve.prefill_s").append(t_prefill)

    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    outs = [tok]
    t0 = monotonic()
    with spans.span("serve.decode", batch=batch, gen=gen):
        for t in range(prompt_len, total - 1):
            logits, cache = decode(params, cache, tok, jnp.int32(t))
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None]
            tok = tok.astype(jnp.int32)
            outs.append(tok)
        jax.block_until_ready(outs)
    t_decode = monotonic() - t0
    reg.series("serve.decode_s").append(t_decode)
    gen_tokens = jnp.concatenate(outs, axis=1)
    return gen_tokens, t_prefill, t_decode


def _run_continuous(cfg, args, registry: MetricRegistry):
    from repro.exec.trace import EventTrace
    from repro.serving import (ContinuousServer, poisson_trace,
                               sample_requests, static_serve_trace)
    if args.arrival_trace:
        trace = EventTrace.load(args.arrival_trace)
    else:
        trace = poisson_trace(args.rate, args.requests, seed=args.seed)
    pmax = max(args.prompt_len, 8)
    reqs = sample_requests(trace, cfg, prompt_range=(max(4, pmax // 4), pmax),
                           gen_range=(max(2, args.gen // 4), args.gen),
                           seed=args.seed)
    max_seq = -(-(pmax + args.gen) // args.page_size) * args.page_size
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    srv = ContinuousServer(cfg, params, slots=args.batch,
                           page_size=args.page_size, max_seq=max_seq,
                           attn_impl=args.attn_impl,
                           gather_mode=args.gather_mode, registry=registry,
                           seed=args.seed)
    for note in registry.notes:           # e.g. pallas_gather ring fallback
        print(f"note: {note}")
    srv.warmup([pmax])
    rep = srv.run(reqs)
    base = static_serve_trace(cfg, reqs, batch=args.batch, params=params)
    slo = args.slo_ms / 1e3
    print(f"arch={cfg.name} continuous: {len(rep.rids)} reqs "
          f"{rep.total_tokens} tok in {rep.makespan:.2f}s "
          f"({rep.throughput:.0f} tok/s) p50={rep.percentile(50) * 1e3:.0f}ms "
          f"p99={rep.percentile(99) * 1e3:.0f}ms "
          f"goodput@{args.slo_ms:.0f}ms={rep.goodput(slo):.0f} tok/s "
          f"occ={rep.occupancy_mean:.2f}/{args.batch}")
    print(f"arch={cfg.name} static    : {base.makespan:.2f}s "
          f"({base.throughput:.0f} tok/s) "
          f"p50={base.percentile(50) * 1e3:.0f}ms "
          f"p99={base.percentile(99) * 1e3:.0f}ms "
          f"goodput@{args.slo_ms:.0f}ms={base.goodput(slo):.0f} tok/s")
    return rep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="mamba2-2.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--mode", choices=("static", "continuous"),
                    default="static")
    ap.add_argument("--batch", type=int, default=4,
                    help="static batch size / continuous decode slots")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="continuous: Poisson arrival rate, req/s")
    ap.add_argument("--requests", type=int, default=16,
                    help="continuous: number of requests")
    ap.add_argument("--arrival-trace", type=str, default="",
                    help="continuous: replay a saved EventTrace .npz "
                         "instead of drawing Poisson arrivals")
    ap.add_argument("--slo-ms", type=float, default=500.0)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--attn-impl",
                    choices=("xla", "pallas", "pallas_gather"),
                    default="xla",
                    help="continuous decode attention: 'pallas' = in-kernel "
                         "paged walk, 'xla' = masked bucketed gather, "
                         "'pallas_gather' = legacy flash-over-a-copy "
                         "(falls back to xla under sliding windows, loudly)")
    ap.add_argument("--gather-mode", choices=("bucket", "full"),
                    default="bucket",
                    help="xla/pallas_gather decode: narrow the dense gather "
                         "to the batch's live page bucket, or pin the "
                         "full-capacity bitwise baseline")
    ap.add_argument("--metrics-out", type=str, default="",
                    help="write the obs metric stream (JSONL) here")
    ap.add_argument("--trace-out", type=str, default="",
                    help="write a Perfetto-viewable Chrome trace here")
    args = ap.parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    registry = MetricRegistry()

    with spans.maybe_traced(bool(args.trace_out)) as tracer:
        if args.mode == "continuous":
            out = _run_continuous(cfg, args, registry)
            toks = out.tokens[int(out.rids[0])]
        else:
            toks, t_prefill, t_decode = serve(cfg, batch=args.batch,
                                              prompt_len=args.prompt_len,
                                              gen=args.gen, seed=args.seed,
                                              registry=registry)
            prefill_tps = args.batch * args.prompt_len / t_prefill
            decode_steps = args.gen - 1   # first generated token: prefill
            if decode_steps > 0:
                decode_msg = (
                    f"decode {decode_steps} steps in {t_decode:.2f}s "
                    f"({args.batch * decode_steps / t_decode:.0f} tok/s)")
            else:
                decode_msg = "decode skipped (all tokens from prefill)"
            print(f"arch={cfg.name} generated {toks.shape}: "
                  f"prefill {args.prompt_len} tok in {t_prefill:.2f}s "
                  f"({prefill_tps:.0f} tok/s), " + decode_msg)

    if args.metrics_out:
        from repro.obs import run_metadata
        run = run_metadata(extra={"arch": args.arch, "mode": args.mode,
                                  "batch": args.batch, "gen": args.gen})
        n = registry.to_jsonl(args.metrics_out, run)
        print(f"metrics -> {args.metrics_out} ({n} records)")
    if args.trace_out:
        from repro.obs import export_chrome_trace
        n = export_chrome_trace(args.trace_out,
                                tracer=tracer if tracer.enabled else None,
                                metrics=registry)
        print(f"chrome trace -> {args.trace_out} ({n} events; open at "
              "https://ui.perfetto.dev)")
    assert bool(jnp.isfinite(jnp.asarray(toks, jnp.float32)).all())
    return toks


if __name__ == "__main__":
    main()
