"""Serving driver: batched prefill + decode with KV cache.

CPU-runnable:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import transformer as T


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0):
    """Batched single-pass prefill (one jitted call fills the whole KV
    cache) + per-token decode loop for the generated suffix. Returns
    (gen_tokens, prefill_seconds, decode_seconds)."""
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(cfg.vocab_size,
                                       size=(batch, prompt_len)), jnp.int32)
    total = prompt_len + gen
    cache = T.init_cache(cfg, batch, total)

    prefill = jax.jit(lambda p, c, toks: T.prefill(p, c, toks, cfg))
    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))

    t0 = time.time()
    logits, cache = jax.block_until_ready(prefill(params, cache, prompts))
    t_prefill = time.time() - t0

    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    outs = [tok]
    t0 = time.time()
    for t in range(prompt_len, total - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(t))
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(outs)
    t_decode = time.time() - t0
    gen_tokens = jnp.concatenate(outs, axis=1)
    return gen_tokens, t_prefill, t_decode


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="mamba2-2.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    toks, t_prefill, t_decode = serve(cfg, batch=args.batch,
                                      prompt_len=args.prompt_len,
                                      gen=args.gen)
    prefill_tps = args.batch * args.prompt_len / t_prefill
    decode_steps = args.gen - 1      # first generated token comes from prefill
    if decode_steps > 0:
        decode_msg = (f"decode {decode_steps} steps in {t_decode:.2f}s "
                      f"({args.batch * decode_steps / t_decode:.0f} tok/s)")
    else:
        decode_msg = "decode skipped (all tokens from prefill)"
    print(f"arch={cfg.name} generated {toks.shape}: "
          f"prefill {args.prompt_len} tok in {t_prefill:.2f}s "
          f"({prefill_tps:.0f} tok/s), " + decode_msg)
    assert bool(jnp.isfinite(jnp.asarray(toks, jnp.float32)).all())
    return toks


if __name__ == "__main__":
    main()
