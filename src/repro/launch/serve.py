"""Serving driver: batched prefill + decode with KV cache.

CPU-runnable:
  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import transformer as T


def serve(cfg, *, batch: int, prompt_len: int, gen: int, seed: int = 0):
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(cfg.vocab_size,
                                       size=(batch, prompt_len)), jnp.int32)
    total = prompt_len + gen
    cache = T.init_cache(cfg, batch, total)
    extra = {}
    if cfg.arch_type == "encdec":
        extra["enc_emb"] = jnp.zeros((batch, cfg.encoder_seq, cfg.d_model),
                                     cfg.dtype("compute"))
    if cfg.arch_type == "vlm":
        extra["img_emb"] = jnp.zeros((batch, cfg.num_image_tokens, cfg.d_model),
                                     cfg.dtype("compute"))

    decode = jax.jit(lambda p, c, t, pos: T.decode_step(p, c, t, pos, cfg))

    # prefill by decoding the prompt (cache-consistent for every arch family)
    tok = prompts[:, :1]
    t0 = time.time()
    outs = []
    for t in range(total - 1):
        logits, cache = decode(params, cache, tok, jnp.int32(t))
        if t + 1 < prompt_len:
            tok = prompts[:, t + 1:t + 2]
        else:
            tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            outs.append(tok)
    dt = time.time() - t0
    gen_tokens = jnp.concatenate(outs, axis=1)
    return gen_tokens, dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="mamba2-2.7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    toks, dt = serve(cfg, batch=args.batch, prompt_len=args.prompt_len,
                     gen=args.gen)
    steps = args.prompt_len + args.gen - 1
    print(f"arch={cfg.name} generated {toks.shape} in {dt:.2f}s "
          f"({dt/steps*1e3:.1f} ms/token-step)")
    assert bool(jnp.isfinite(jnp.asarray(toks, jnp.float32)).all())
    return toks


if __name__ == "__main__":
    main()
