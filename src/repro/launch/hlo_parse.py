"""Trip-count-aware analysis of optimized (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so for
scan-over-layers models it understates FLOPs and collective bytes by the
trip counts. This parser rebuilds the call graph (entry -> fusions/calls ->
while bodies), extracts each loop's trip count from its condition
computation, and accumulates:

  - dot FLOPs (2 * M*N*K, from result shape x contracting dims)
  - convolution FLOPs
  - collective bytes by op type (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute)

each weighted by the product of enclosing trip counts.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_SHAPE_RE = re.compile(r"^\(?([a-z0-9]+)\[([0-9,]*)\]")
_SHAPES_ALL = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_CALL_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                      r"[{]?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)[}]?")
_CONST_RE = re.compile(r"=\s*[su]\d+\[\]\s+constant\((\d+)\)")
# operand with optional inline type annotation, e.g.
#   dot(f32[8,32]{1,0} %copy.10, ...)   vs   dot(%copy.10, ...)
_TY = r"(?:([a-z0-9]+)\[([0-9,]*)\](?:\{[^}]*\})?\s+)?"
_DOT_OPS = re.compile(r"\bdot\(\s*" + _TY + r"%?([\w.\-]+)")
_CONV_OPS = re.compile(r"convolution\(\s*" + _TY + r"%?([\w.\-]+)\s*,\s*" +
                       _TY + r"%?([\w.\-]+)")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _shape_elems(dims) * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class Computation:
    name: str
    lines: List[str]
    param_shapes: Dict[str, str]


def split_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and stripped.endswith("{"):
                cur = Computation(m.group(1), [], {})
        else:
            if stripped == "}":
                comps[cur.name] = cur
                cur = None
            else:
                cur.lines.append(stripped)
    return comps


def _value_shapes(comp: Computation) -> Dict[str, Tuple[str, str]]:
    """Map %name -> (dtype, dims) from def lines (first shape in the rhs)."""
    shapes: Dict[str, Tuple[str, str]] = {}
    for line in comp.lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        s = _SHAPE_RE.match(rhs)
        if s:
            shapes[name] = (s.group(1), s.group(2))
    return shapes


def _dot_flops(line: str, shapes: Dict[str, Tuple[str, str]]) -> float:
    """2 * prod(result_dims) * prod(contracting_dims of lhs)."""
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    rhs = m.group(2)
    rs = _SHAPE_RE.match(rhs)
    if not rs:
        return 0.0
    result_elems = _shape_elems(rs.group(2))
    ops = _DOT_OPS.search(rhs)
    if not ops:
        return 0.0
    # inline operand shape if present (current HLO text), else def lookup
    lhs = ((ops.group(1), ops.group(2)) if ops.group(1) is not None
           else shapes.get(ops.group(3)))
    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    if lhs is None or cdims is None:
        return 0.0
    ldims = [int(d) for d in lhs[1].split(",")] if lhs[1] else []
    k = 1
    for idx in (cdims.group(1).split(",") if cdims.group(1) else []):
        i = int(idx)
        if i < len(ldims):
            k *= ldims[i]
    return 2.0 * result_elems * k


def _conv_flops(line: str, shapes: Dict[str, Tuple[str, str]]) -> float:
    m = _DEF_RE.match(line)
    if not m:
        return 0.0
    rhs = m.group(2)
    if " convolution(" not in rhs and not rhs.startswith("convolution("):
        return 0.0
    rs = _SHAPE_RE.match(rhs)
    ops = _CONV_OPS.search(rhs)
    if not rs or not ops:
        return 0.0
    result_elems = _shape_elems(rs.group(2))
    ker = ((ops.group(4), ops.group(5)) if ops.group(4) is not None
           else shapes.get(ops.group(6)))
    if ker is None:
        return 0.0
    kdims = [int(d) for d in ker[1].split(",")] if ker[1] else []
    # flops = 2 * out_elems * (kernel spatial x input channels) ~ prod(kdims)/Cout
    kelems = 1
    for d in kdims:
        kelems *= d
    cout = kdims[-1] if kdims else 1   # HWIO default from our models
    return 2.0 * result_elems * max(kelems // max(cout, 1), 1)


@dataclasses.dataclass
class ModuleStats:
    dot_flops: float
    conv_flops: float
    collective_bytes: Dict[str, float]
    collective_counts: Dict[str, float]
    trip_counts: Dict[str, int]

    @property
    def flops(self) -> float:
        return self.dot_flops + self.conv_flops

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def _find_trip_count(cond: Computation) -> int:
    consts = [int(c) for c in _CONST_RE.findall("\n".join(cond.lines))]
    big = [c for c in consts if c > 1]
    return max(big) if big else (max(consts) if consts else 1)


def analyze_module(text: str) -> ModuleStats:
    comps = split_computations(text)
    entry = None
    for name in comps:
        pass
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
    entry = m.group(1) if m else next(iter(comps))

    # accumulate multipliers over the call DAG
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # BFS respecting call edges; loops in call graph don't exist in HLO
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps[cname]
        base = mult[cname]
        for line in comp.lines:
            wm = re.search(r"\bwhile\(", line)
            body = re.search(r"body=%?([\w.\-]+)", line)
            cond = re.search(r"condition=%?([\w.\-]+)", line)
            if wm and body and cond and body.group(1) in comps:
                trips = _find_trip_count(comps[cond.group(1)]) \
                    if cond.group(1) in comps else 1
                for callee, factor in ((body.group(1), trips),
                                       (cond.group(1), trips + 1)):
                    if callee in comps:
                        mult[callee] += base * factor
                        if callee not in seen:
                            seen.add(callee)
                            order.append(callee)
                continue
            cm = _CALL_RE.search(line)
            if cm:
                for callee in re.split(r",\s*", cm.group(1)):
                    callee = callee.lstrip("%")
                    if callee in comps:
                        mult[callee] += base
                        if callee not in seen:
                            seen.add(callee)
                            order.append(callee)

    dot_flops = 0.0
    conv_flops = 0.0
    cbytes = {c: 0.0 for c in _COLLECTIVES}
    ccount = {c: 0.0 for c in _COLLECTIVES}
    trips: Dict[str, int] = {}
    for name, comp in comps.items():
        w = mult.get(name, 0.0)
        if w <= 0:
            continue
        shapes = _value_shapes(comp)
        for line in comp.lines:
            if " dot(" in line or re.search(r"=\s*\S+\s+dot\(", line):
                dot_flops += w * _dot_flops(line, shapes)
            if "convolution(" in line:
                conv_flops += w * _conv_flops(line, shapes)
            for coll in _COLLECTIVES:
                if re.search(rf"\b{coll}(?:-start)?\(", line):
                    m2 = _DEF_RE.match(line)
                    if not m2:
                        continue
                    rhs = m2.group(2)
                    if rhs.startswith("("):
                        total = sum(_shape_bytes(d, s) for d, s in
                                    _SHAPES_ALL.findall(rhs.split(coll)[0]))
                    else:
                        rs = _SHAPE_RE.match(rhs)
                        total = _shape_bytes(*rs.groups()) if rs else 0
                    cbytes[coll] += w * total
                    ccount[coll] += w
                    break
    return ModuleStats(dot_flops=dot_flops, conv_flops=conv_flops,
                       collective_bytes=cbytes, collective_counts=ccount,
                       trip_counts=trips)
