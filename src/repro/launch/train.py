"""End-to-end training driver — argument parsing in front of the unified
execution engine (``repro.engine``). The engine owns the loop: mesh-
sharded grouped step (real SPMD over a ("group","data") device split when
devices are available), strategy plugins, prefetch, donation, telemetry,
checkpoint hooks, trace replay.

CPU-runnable examples (reduced archs, real data pipeline, Omnivore
compute groups + strategies):

  # token LM, 4 async compute groups
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 60 --groups 4 --momentum 0.3 --lr 0.05

  # the paper's own workload family: CNN with the merged-FC sync head
  PYTHONPATH=src python -m repro.launch.train --arch lenet --smoke \
      --steps 60 --groups 4 --momentum 0.3 --lr 0.05

  # 8 real host devices: XLA_FLAGS=--xla_force_host_platform_device_count=8

Heterogeneous planning (--cluster-spec ... --plan) picks g, the
device->group packing and throughput-proportional batch shares; trace
replay (--replay-trace trace.npz) executes along a recorded event
schedule. Both run through the same Engine.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config, list_archs
from repro.data.pipeline import DataConfig, SyntheticImages, SyntheticLM
from repro.engine import Engine
from repro.models import cnn as C
from repro.models import transformer as T
from repro.optim.sgd import init_momentum


def _build_workload(args):
    """(name, params, loss_fn, data_iterable, head_filter) per --arch."""
    if args.arch in C.CNN_CONFIGS:
        import dataclasses
        cfg = C.get_cnn_smoke_config(args.arch) if args.smoke \
            else C.get_cnn_config(args.arch)
        if args.conv_impl:
            cfg = dataclasses.replace(cfg, conv_impl=args.conv_impl)
        if cfg.conv_impl == "lowering_interpret":
            # probe + cache (b_p, r_b) per conv layer before the engine
            # compiles the step (paper Fig. 4 b_p sweep, automated).
            # Probe at the per-group batch — the shape the engine actually
            # traces each conv at (the cache key ignores the batch dim, so
            # per-device shards still hit)
            tiles = C.autotune_conv_tiles(cfg,
                                          max(1, args.batch // args.groups))
            print("autotuned conv tiles: " + ", ".join(
                f"layer{i}(bp={bp},rb={rb})"
                for i, (bp, rb) in sorted(tiles.items())))
        params = C.init_params(jax.random.PRNGKey(args.seed), cfg)
        data = SyntheticImages(DataConfig(
            batch_size=args.batch, image_size=cfg.image_size,
            channels=cfg.in_channels, num_classes=cfg.num_classes,
            seed=args.seed))
        return (cfg.name, params, lambda p, b: C.loss_fn(p, b, cfg),
                data.batches(args.steps), C.head_filter, cfg)
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.arch_type in ("encdec", "vlm"):
        raise SystemExit("train.py drives token-LM and CNN archs; see "
                         "examples/ for the modality-stub variants")
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    data = SyntheticLM(DataConfig(batch_size=args.batch, seq_len=args.seq,
                                  vocab_size=cfg.vocab_size, seed=args.seed))
    return (cfg.name, params, lambda p, b: T.lm_loss(p, b, cfg),
            data.batches(args.steps), None, cfg)


def _plan(args, params, cfg):
    """Heterogeneous plan: g, device->group packing, batch shares."""
    from repro import cluster
    devices = cluster.parse_cluster_spec(args.cluster_spec)
    n_params = sum(int(p.size) for p in jax.tree.leaves(params))
    tokens = args.seq if hasattr(cfg, "vocab_size") else 1
    # rough roofline: ~6*P FLOPs per token fwd+bwd, one param sweep of
    # memory traffic per example, fp32 gradient payload
    # fp32 params + fp32 momentum resident per model replica
    cost = cluster.WorkloadCost(flops_per_example=6.0 * n_params * tokens,
                                bytes_per_example=4.0 * n_params,
                                grad_bytes=4.0 * n_params,
                                state_bytes=8.0 * n_params)
    # merged-FC phase ~ the head matmul on the full batch on the fastest
    # device (unembed for LMs, the FC stack for CNNs)
    if hasattr(cfg, "vocab_size"):
        head_flops = 6.0 * cfg.d_model * cfg.vocab_size * args.seq
    else:
        head_flops = 6.0 * sum(int(np.prod(p["w"].shape))
                               for p in params["fc"])
    t_fc = args.batch * head_flops / max(d.peak_flops for d in devices)
    # 2-D (g, mp) search: powers of two up to the smallest group's size;
    # infeasible points (memory, group width) are skipped by the planner
    n = len(devices)
    mp_candidates = [m for m in (1, 2, 4, 8, 16) if m <= n]
    plan = cluster.best_allocation(devices, global_batch=args.batch,
                                   t_fc=t_fc, cost=cost,
                                   mp_candidates=mp_candidates)
    print(plan.describe())
    return plan


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch",
                    choices=[*list_archs(), *sorted(C.CNN_CONFIGS)],
                    default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--groups", type=int, default=1,
                    help="compute groups g (paper's execution strategy)")
    ap.add_argument("--mp", type=int, default=1,
                    help="model-parallel devices per worker: shards "
                         "params/optimizer state over the mesh's 'mp' "
                         "axis (sharding.rules.engine_param_specs); the "
                         "device budget becomes groups*k*mp. --plan "
                         "overrides this with the planner's (g, mp) pick")
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--strategy",
                    choices=("sync", "grouped-fused", "grouped-scan"),
                    default="grouped-fused",
                    help="engine strategy (sync is the g=1 reduction; "
                         "--replay-trace switches to trace-replay)")
    ap.add_argument("--exec-mode",
                    choices=("auto", "spmd", "reference", "vmap"),
                    default="auto",
                    help="step placement: SPMD group mesh when devices "
                         "allow (auto), forced mesh, the bit-exact "
                         "single-device reference, or the legacy vmap path")
    ap.add_argument("--update-impl", choices=("xla", "pallas"), default="xla",
                    help="leaf kernel for the fused update (pallas runs "
                         "interpret-mode off-TPU)")
    ap.add_argument("--bucket-bytes", type=int, default=None,
                    help="slab size target of the SPMD step's overlapped "
                         "bucketed gradient exchange (0 = legacy "
                         "whole-tree gather; default engine.spmd."
                         "DEFAULT_BUCKET_BYTES)")
    ap.add_argument("--conv-impl",
                    choices=("xla", "lowering", "lowering_interpret",
                             "lowering_autodiff"),
                    default="",
                    help="CNN conv path (CNN archs only): lowering = "
                         "custom-VJP batched-GEMM train path (config "
                         "default), lowering_interpret = Pallas kernels "
                         "with per-layer autotuned tiles, "
                         "lowering_autodiff = generic-autodiff baseline, "
                         "xla = native conv")
    ap.add_argument("--replay-trace", type=str, default="",
                    help="replay a recorded event trace (.npz EventTrace): "
                         "one per-commit stale update per trace commit "
                         "instead of round-robin rounds (truncated to "
                         "--steps commits)")
    ap.add_argument("--replay-impl", choices=("scan", "python", "fused"),
                    default="scan")
    ap.add_argument("--replay-depth", type=int, default=0,
                    help="cap the replay parameter-history ring "
                         "(0 = full max-staleness depth)")
    ap.add_argument("--cluster-spec", type=str, default="",
                    help="heterogeneous cluster, e.g. "
                         "'8xgpu-g2.2xlarge,8xcpu-c4.4xlarge'")
    ap.add_argument("--plan", action="store_true",
                    help="plan g / device packing / batch shares over "
                         "--cluster-spec and train share-weighted "
                         "(overrides --groups)")
    ap.add_argument("--ckpt", type=str, default="")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", type=str, default="",
                    help="sink the run's metric stream (step_s, "
                         "data_wait_s, h2d_s, loss, ...) to this JSONL "
                         "file (schema: repro.obs.metrics)")
    ap.add_argument("--trace-out", type=str, default="",
                    help="export a Chrome trace-event JSON of the run's "
                         "spans + metrics to this file (open in Perfetto)")
    args = ap.parse_args(argv)
    if args.plan and not args.cluster_spec:
        ap.error("--plan requires --cluster-spec")
    if args.conv_impl and args.arch not in C.CNN_CONFIGS:
        ap.error(f"--conv-impl applies to CNN archs "
                 f"({', '.join(sorted(C.CNN_CONFIGS))}), not {args.arch}")
    if args.plan and args.replay_trace:
        ap.error("--plan and --replay-trace are mutually exclusive "
                 "(a replay executes a recorded schedule; there is "
                 "nothing for the planner to allocate)")

    # install a recording span tracer for the whole run (workload build,
    # autotune probes, engine loop) iff a trace export was requested —
    # otherwise every span below stays the shared no-op
    from repro.obs import spans
    with spans.maybe_traced(bool(args.trace_out)):
        return _run(args)


def _export_obs(args, engine, groups: int, event_trace=None) -> None:
    """Sink the run's metric stream / Chrome trace when requested."""
    if not (args.metrics_out or args.trace_out):
        return
    from repro.obs import export_chrome_trace, run_metadata
    if args.metrics_out:
        strategy = "trace-replay" if args.replay_trace else args.strategy
        run = run_metadata(extra={"arch": args.arch, "groups": groups,
                                  "batch": args.batch, "steps": args.steps,
                                  "strategy": strategy})
        n = engine.telemetry.registry.to_jsonl(args.metrics_out, run)
        print(f"metrics -> {args.metrics_out} ({n} records)")
    if args.trace_out:
        tracer = engine.tracer if engine.tracer.enabled else None
        n = export_chrome_trace(args.trace_out, tracer=tracer,
                                metrics=engine.telemetry.registry,
                                event_trace=event_trace)
        print(f"chrome trace -> {args.trace_out} ({n} events; open at "
              "https://ui.perfetto.dev)")


def _run(args):
    name, params, loss_fn, data, head_filter, cfg = _build_workload(args)
    mom = init_momentum(params)

    if args.replay_trace:
        from repro.exec import EventTrace
        trace = EventTrace.load(args.replay_trace)
        engine = Engine(loss_fn, strategy="trace-replay", trace=trace,
                        lr=args.lr, momentum=args.momentum,
                        weight_decay=args.weight_decay,
                        replay_impl=args.replay_impl,
                        replay_depth=args.replay_depth or None)
        t = trace.truncate(args.steps)
        if len(t) == 0:
            raise SystemExit(f"{args.replay_trace} has no commits to replay "
                             f"(after truncation to --steps {args.steps})")
        print(f"arch={name} replaying {args.replay_trace}: {len(t)} commits, "
              f"g={trace.num_groups}, mean staleness "
              f"{float(t.staleness.mean()):.2f}, max {t.max_staleness}")
        # one microbatch per commit: the per-commit stream uses batch-size
        # microbatches, matching the per-group share of a grouped round
        _, _, losses = engine.run(params, mom, data, steps=args.steps,
                                  log_every=10)
        print(f"final loss {np.mean(losses[-5:]):.4f} "
              f"(impl={args.replay_impl})")
        _export_obs(args, engine, trace.num_groups, event_trace=t)
        return losses

    groups, group_weights, micro_sizes, mp = args.groups, None, None, args.mp
    if args.plan:
        plan = _plan(args, params, cfg)
        groups, group_weights = plan.g, plan.weights
        micro_sizes = plan.allocation.microbatches
        mp = plan.mp
        # the plan's mp is sized for the --cluster-spec devices; when this
        # process emulates the run on a smaller local pool (the smoke
        # default: 1 host device), mp-sharded storage has no mesh to live
        # on — store unsharded and keep the rest of the plan
        if args.exec_mode == "auto" and mp > 1 \
                and jax.device_count() < groups * mp:
            print(f"plan chose mp={mp} for the cluster; local pool has "
                  f"{jax.device_count()} device(s) < g*mp={groups * mp} — "
                  "storing params unsharded here (mp=1)")
            mp = 1

    engine = Engine(loss_fn, strategy=args.strategy, num_groups=groups,
                    lr=args.lr, momentum=args.momentum,
                    weight_decay=args.weight_decay,
                    group_weights=group_weights, micro_sizes=micro_sizes,
                    head_filter=head_filter, update_impl=args.update_impl,
                    exec_mode=args.exec_mode, mp=mp,
                    **({"bucket_bytes": args.bucket_bytes}
                       if args.bucket_bytes is not None else {}),
                    checkpoint_dir=args.ckpt,
                    checkpoint_every=args.steps if args.ckpt else 0)
    print(f"arch={name} {engine.describe(groups, args.batch // groups)}"
          + (" (planned)" if args.plan else ""))
    params, mom, losses = engine.run(params, mom, data, steps=args.steps,
                                     log_every=10)
    print(f"final loss {np.mean(losses[-5:]):.4f}")
    summary = engine.telemetry.summary(batch_size=args.batch)
    print(f"telemetry: {summary['median_step_ms']:.1f} ms/step median, "
          f"{summary['examples_per_s']:.0f} examples/s, "
          f"{summary['data_wait_ms']:.1f} ms/step host data wait")
    _export_obs(args, engine, groups)
    if args.ckpt:
        print("checkpointed to", args.ckpt)
    return losses


if __name__ == "__main__":
    main()
