"""End-to-end training driver.

CPU-runnable example (reduced arch, real data pipeline, Omnivore compute
groups + Algorithm 1):

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 60 --groups 4 --momentum 0.3 --lr 0.05

Heterogeneous planning (the cluster subsystem picks g, the device->group
packing and throughput-proportional batch shares; the step then applies
share-weighted grouped updates):

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --smoke \
      --steps 20 --cluster-spec 8xgpu-g2.2xlarge,8xcpu-c4.4xlarge --plan

On a real cluster the same driver runs the full config on the production
mesh (--mesh prod[,multipod]).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


from repro.checkpoint import checkpointing as CK
from repro.configs import get_config, get_smoke_config, list_archs
from repro.core.async_sgd import make_grouped_train_step
from repro.core.compute_groups import GroupSpec, group_batch_split
from repro.data.pipeline import DataConfig, SyntheticLM, prefetch
from repro.models import transformer as T
from repro.optim.sgd import init_momentum


def _replay_main(args, cfg, params, loss_fn):
    """--replay-trace: drive a smoke run along a recorded event trace —
    the executed counterpart of the simulators' staleness predictions."""
    from repro.exec import EventTrace, replay_trace

    trace = EventTrace.load(args.replay_trace).truncate(args.steps)
    T = len(trace)
    if T == 0:
        raise SystemExit(f"{args.replay_trace} has no commits to replay "
                         f"(after truncation to --steps {args.steps})")
    print(f"arch={cfg.name} replaying {args.replay_trace}: {T} commits, "
          f"g={trace.num_groups}, mean staleness "
          f"{float(trace.staleness.mean()):.2f}, max {trace.max_staleness}")
    data = SyntheticLM(DataConfig(batch_size=args.batch, seq_len=args.seq,
                                  vocab_size=cfg.vocab_size, seed=args.seed))
    # one microbatch per commit, stacked to a (T, ...) leading axis
    batches = jax.tree.map(lambda *xs: jnp.stack(xs),
                           *list(data.batches(T)))
    t0 = time.time()
    _, losses, _ = replay_trace(
        loss_fn, params, batches, trace, lr=args.lr,
        momentum=args.momentum, weight_decay=args.weight_decay,
        impl=args.replay_impl,
        depth=args.replay_depth or None)
    losses = np.asarray(losses)
    dt = time.time() - t0
    for i in range(0, T, 10):
        print(f"commit {i:5d} loss {float(losses[i]):.4f}")
    print(f"final loss {losses[-5:].mean():.4f} "
          f"({dt / T * 1e3:.0f} ms/commit, impl={args.replay_impl})")
    return losses.tolist()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_archs(), default="qwen2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--groups", type=int, default=1,
                    help="compute groups g (paper's execution strategy)")
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--momentum", type=float, default=0.9)
    ap.add_argument("--weight-decay", type=float, default=0.0)
    ap.add_argument("--strategy", choices=("fused", "scan"), default="fused",
                    help="grouped update: closed-form fused pass (default) "
                         "or the literal O(g) sequential scan reference")
    ap.add_argument("--update-impl", choices=("xla", "pallas"), default="xla",
                    help="leaf kernel for the fused update (pallas runs "
                         "interpret-mode off-TPU)")
    ap.add_argument("--replay-trace", type=str, default="",
                    help="replay a recorded event trace (.npz saved from "
                         "queue_sim/cluster-sim EventTrace): executes one "
                         "per-commit stale update per trace commit instead "
                         "of the round-robin grouped step (truncated to "
                         "--steps commits)")
    ap.add_argument("--replay-impl", choices=("scan", "python", "fused"),
                    default="scan",
                    help="replay engine: jittable lax.scan (default), the "
                         "Python reference, or the closed-form fused path "
                         "(run-structured traces only)")
    ap.add_argument("--replay-depth", type=int, default=0,
                    help="cap the replay parameter-history ring; commits "
                         "staler than the ring read its oldest version "
                         "(0 = full max-staleness depth)")
    ap.add_argument("--cluster-spec", type=str, default="",
                    help="heterogeneous cluster, e.g. "
                         "'8xgpu-g2.2xlarge,8xcpu-c4.4xlarge' "
                         "(see repro.cluster.devices registry)")
    ap.add_argument("--plan", action="store_true",
                    help="run the time-to-convergence planner over "
                         "--cluster-spec: picks g, packs devices into "
                         "groups, splits the batch by throughput and "
                         "weights the grouped updates accordingly "
                         "(overrides --groups)")
    ap.add_argument("--ckpt", type=str, default="")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.plan and not args.cluster_spec:
        ap.error("--plan requires --cluster-spec")

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.arch_type in ("encdec", "vlm"):
        raise SystemExit("train.py drives token-LM archs; see examples/ for "
                         "the modality-stub variants")

    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    mom = init_momentum(params)

    def loss_fn(p, batch):
        return T.lm_loss(p, batch, cfg)

    if args.replay_trace:
        return _replay_main(args, cfg, params, loss_fn)

    groups, group_weights, micro_sizes = args.groups, None, None
    if args.plan:
        from repro import cluster
        devices = cluster.parse_cluster_spec(args.cluster_spec)
        n_params = sum(int(p.size) for p in jax.tree.leaves(params))
        # rough transformer roofline: ~6*P FLOPs per token fwd+bwd, one
        # param sweep of memory traffic per example, fp32 gradient payload
        cost = cluster.WorkloadCost(
            flops_per_example=6.0 * n_params * args.seq,
            bytes_per_example=4.0 * n_params,
            grad_bytes=4.0 * n_params)
        # merged-FC phase ~ the unembed matmul on the full batch, served by
        # the fastest device in the cluster
        head_flops = 6.0 * cfg.d_model * cfg.vocab_size * args.seq
        t_fc = args.batch * head_flops / max(d.peak_flops for d in devices)
        plan = cluster.best_allocation(devices, global_batch=args.batch,
                                       t_fc=t_fc, cost=cost)
        print(plan.describe())
        groups = plan.g
        group_weights = plan.weights
        micro_sizes = plan.allocation.microbatches

    # donate params/momentum: the fused update rewrites them in place
    # instead of holding both generations live. The Pallas leaf kernel
    # compiles natively on TPU and falls back to interpret mode elsewhere.
    step = jax.jit(make_grouped_train_step(
        loss_fn, num_groups=groups, lr=args.lr, momentum=args.momentum,
        weight_decay=args.weight_decay, strategy=args.strategy,
        update_impl=args.update_impl, group_weights=group_weights),
        donate_argnums=(0, 1))

    data = SyntheticLM(DataConfig(batch_size=args.batch, seq_len=args.seq,
                                  vocab_size=cfg.vocab_size, seed=args.seed))
    if args.plan:
        spec = GroupSpec(num_groups=groups, num_devices=groups)
        print(f"arch={cfg.name} g={groups} (planned) S={spec.staleness} "
              f"mu_implicit={spec.implicit_momentum:.3f}")
    else:
        spec = GroupSpec(num_groups=groups,
                         num_devices=max(groups, jax.device_count()))
        print(f"arch={cfg.name} g={groups} S={spec.staleness} "
              f"mu_implicit={spec.implicit_momentum:.3f}")

    losses = []
    t0 = time.time()
    for i, batch in enumerate(prefetch(data.batches(args.steps))):
        gb = group_batch_split(batch, groups, sizes=micro_sizes)
        params, mom, loss = step(params, mom, gb)
        losses.append(float(loss))
        if i % 10 == 0:
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"({(time.time()-t0)/(i+1)*1e3:.0f} ms/it)")
    print(f"final loss {np.mean(losses[-5:]):.4f}")
    if args.ckpt:
        CK.save(f"{args.ckpt}/ckpt_{args.steps:07d}",
                {"params": params, "mom": mom}, step=args.steps)
        print("checkpointed to", args.ckpt)
    return losses


if __name__ == "__main__":
    main()
