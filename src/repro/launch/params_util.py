"""Parameter counting (total and MoE-active) from eval_shape specs."""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.base import ArchConfig


def param_count(params_shapes) -> int:
    return int(sum(np.prod(l.shape) for l in jax.tree.leaves(params_shapes)))


def param_bytes(params_shapes) -> int:
    return int(sum(np.prod(l.shape) * l.dtype.itemsize
                   for l in jax.tree.leaves(params_shapes)))


def active_param_count(params_shapes, cfg: ArchConfig) -> int:
    """MoE: per-token active params = non-expert params + top_k/E of routed
    expert params (+ shared experts, always active)."""
    if cfg.moe is None:
        return param_count(params_shapes)
    total = 0
    routed = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_shapes)[0]:
        keys = [getattr(e, "key", None) for e in path]
        n = int(np.prod(leaf.shape))
        if any(k in ("w_gate", "w_up", "w_down") for k in keys) \
                and "shared" not in keys and "mlp" not in keys \
                and leaf.ndim >= 3:
            routed += n
        else:
            total += n
    return total + routed * cfg.moe.top_k // cfg.moe.num_experts
