"""Post-partitioning HLO analysis: collective bytes + roofline terms.

``cost_analysis()`` has no collective volumes, so we parse the optimized
(SPMD-partitioned) HLO from ``compiled.as_text()`` and sum the result-shape
bytes of every collective op (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  %all-reduce.5 = f32[256,1024]{1,0} all-reduce(%dot), ...
_OP_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_TUPLE_OP_RE = re.compile(
    r"=\s*\(\s*(.+?)\)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_type: Dict[str, int]
    count_by_type: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_type.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_type.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    bytes_by: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    count_by: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:          # avoid double counting start/done pairs
            continue
        m = _OP_RE.search(line)
        if m:
            dtype, dims, op = m.groups()
            bytes_by[op] += _shape_bytes(dtype, dims)
            count_by[op] += 1
            continue
        m = _TUPLE_OP_RE.search(line)
        if m:
            shapes, op = m.groups()
            total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(shapes))
            bytes_by[op] += total
            count_by[op] += 1
    return CollectiveStats(bytes_by, count_by)


@dataclasses.dataclass
class Roofline:
    """Three-term roofline (per chip, seconds) — EXPERIMENTS.md §Roofline."""
    flops: float                   # per-chip HLO flops
    hbm_bytes: float               # per-chip bytes accessed
    collective_bytes: float        # per-chip collective bytes moved
    chips: int
    peak_flops: float = 197e12     # TPU v5e bf16
    hbm_bw: float = 819e9
    link_bw: float = 50e9          # ICI per link

    @property
    def t_compute(self) -> float:
        return self.flops / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / self.link_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    def as_dict(self) -> dict:
        return {"flops": self.flops, "hbm_bytes": self.hbm_bytes,
                "collective_bytes": self.collective_bytes, "chips": self.chips,
                "t_compute": self.t_compute, "t_memory": self.t_memory,
                "t_collective": self.t_collective,
                "bottleneck": self.bottleneck, "step_time": self.step_time}


def roofline_from_compiled(compiled, chips: int,
                           hbm_bytes: Optional[float] = None) -> Roofline:
    """Trip-count-aware roofline from the compiled artifact. XLA's
    cost_analysis counts while bodies once, so FLOPs and collective bytes
    come from the hlo_parse call-graph walk; the HBM term uses the analytic
    traffic model when provided (cost_analysis 'bytes accessed' double counts
    across fusions and also misses loop trips)."""
    from repro.launch.hlo_parse import analyze_module
    stats = analyze_module(compiled.as_text())
    if hbm_bytes is None:
        ca = compiled.cost_analysis()
        hbm_bytes = float(ca.get("bytes accessed", 0.0))
    return Roofline(flops=float(stats.flops),
                    hbm_bytes=float(hbm_bytes),
                    collective_bytes=float(stats.total_collective_bytes),
                    chips=chips)


def analytic_hbm_bytes(cfg, shape, chips: int, *, grad_accum: int = 1,
                       params_bytes_global: float = 0.0,
                       cache_bytes_global: float = 0.0) -> float:
    """Per-chip HBM traffic model (the roofline memory term):

    train:   3x params (fwd read, bwd read, update write) + 2x momentum +
             saved activations written+read once each (remat recomputes
             instead of storing, so only layer-boundary residuals count).
    prefill: params read once + activations + cache write.
    decode:  params read once (one token!) + full cache read + write.
    """
    act_dtype = cfg.dtype("compute").itemsize
    L = max(cfg.num_layers, 1)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        act = tokens * cfg.d_model * act_dtype * L * 2.0
        return (5.0 * params_bytes_global + act) / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        act = tokens * cfg.d_model * act_dtype * L * 2.0
        return (params_bytes_global + act + cache_bytes_global) / chips
    # decode
    return (params_bytes_global + 2.0 * cache_bytes_global
            + shape.global_batch * cfg.d_model * act_dtype * L * 2.0) / chips


def model_flops_6nd(num_params: float, tokens: float) -> float:
    """MODEL_FLOPS = 6 * N * D (dense); pass N_active for MoE."""
    return 6.0 * num_params * tokens
